//! The cycle engine: layer pipeline with DRAM prefetch masking.
//!
//! Execution model per layer `i`:
//!
//! 1. its weights stream from DRAM into weight memory — prefetched
//!    behind layer `i-1`'s fabric cycles, so only the *exposed* part
//!    stalls (`transfer - prev_busy`, clamped at 0);
//! 2. weight rows are written into the PIM cores (`load_cycles`);
//! 3. compute streams all output pixels bit-serially
//!    (`compute_cycles`), with the merge flush at each pass boundary;
//! 4. outputs bounce through the ping-pong memory (accounted as SRAM
//!    energy; the swap itself is free).
//!
//! Alongside the cycle math the engine books capacity pressure: each
//! transfer's hidden/exposed split accumulates on the [`Dram`] model
//! (feeding `RunStats::prefetch_overlap_ratio`), and each layer records
//! how many weight-reload passes it needs through the weight memory and
//! its occupancy demand — pure observability; totals are unchanged.

use crate::arch::cost::CostModel;
use crate::arch::dram::Dram;
use crate::arch::mem::{Buffer, PingPong};
use crate::config::{ArchConfig, SimConfig};
use crate::mapping::{plan_network, LayerPlan};
use crate::model::Network;

use super::stats::{LayerStats, RunStats};

/// A configured simulation instance.
pub struct Simulation {
    pub arch: ArchConfig,
    pub sim: SimConfig,
    pub cost: CostModel,
}

impl Simulation {
    pub fn new(arch: ArchConfig, sim: SimConfig) -> Self {
        let cost = CostModel::new(arch.clone());
        Simulation { arch, sim, cost }
    }

    /// Run the plans through the pipeline.
    pub fn run(&self, plans: &[LayerPlan], input_bytes: u64) -> RunStats {
        let mut dram = Dram::new(self.arch.dram_bytes_per_cycle, self.arch.dram_latency_cycles);
        let mut weight_mem = Buffer::new("weight_mem", self.arch.weight_mem_kb);
        let mut pingpong = PingPong::new(self.arch.pingpong_kb);
        let batch = self.sim.batch.max(1) as u64;

        let mut layers = Vec::with_capacity(plans.len());
        let mut total_cycles: u64 = 0;
        // the input image itself streams from DRAM before layer 0
        let mut prev_busy: u64 = 0;
        let input_transfer = dram.transfer(input_bytes as usize);
        let mut pending_transfer = input_transfer;

        for plan in plans {
            // --- DRAM: this layer's weights were prefetched behind the
            // previous layer's busy cycles
            let wbytes = plan.dram_weight_bytes;
            let wtransfer = dram.transfer(wbytes as usize);
            let total_transfer = pending_transfer + wtransfer;
            let exposed = dram.exposed_cycles(total_transfer, prev_busy);
            // book the hidden/exposed split on the DRAM model — the
            // overlap-ratio observability; the cycle math is unchanged
            dram.hidden_cycles += total_transfer - exposed;
            dram.stalled_cycles += exposed;

            // weight memory staging (layer-by-layer, §III-D)
            weight_mem.reset();
            let capacity = weight_mem.capacity().max(1);
            let staged = (wbytes as usize).min(weight_mem.capacity());
            weight_mem.alloc(staged);
            // capacity pressure: passes the weights need through the
            // memory, and the (unclamped) occupancy they demand
            let reload_passes = (wbytes as usize).div_ceil(capacity) as u64;
            let weight_occupancy = wbytes as f64 / capacity as f64;

            // --- fabric
            let compute = plan.compute_cycles * batch;
            let busy = plan.load_cycles + compute + plan.merge_cycles;
            let cycles = busy + exposed;

            // --- activations through the ping-pong memory
            let act_bytes = plan.sram_act_bytes * batch;
            let bank_cap = pingpong.bank_capacity();
            let _fits = pingpong.write_bank().alloc((act_bytes as usize).min(bank_cap));
            pingpong.swap();

            let energy = self.cost.run_energy_mj(
                plan.macs * batch,
                act_bytes + 2 * staged as u64,
                wbytes,
            );

            layers.push(LayerStats {
                name: plan.name.clone(),
                kind: plan.kind,
                cycles,
                compute_cycles: compute,
                load_cycles: plan.load_cycles,
                exposed_dram_cycles: exposed,
                macs: plan.macs * batch,
                dram_bytes: wbytes,
                sram_bytes: act_bytes,
                energy_mj: energy,
                fcc: plan.fcc,
                reload_passes,
                weight_occupancy,
            });
            total_cycles += cycles;
            prev_busy = busy;
            pending_transfer = 0;
        }

        let total_macs: u64 = layers.iter().map(|l| l.macs).sum();
        let total_dram: u64 = layers.iter().map(|l| l.dram_bytes).sum::<u64>() + input_bytes;
        let total_energy: f64 = layers.iter().map(|l| l.energy_mj).sum();
        RunStats {
            layers,
            total_cycles,
            total_macs,
            total_dram_bytes: total_dram,
            total_energy_mj: total_energy,
            freq_mhz: self.arch.freq_mhz,
            hidden_dram_cycles: dram.hidden_cycles,
            // the cycle model is fault-free; serving/selfcheck attach
            // the functional session's tally via attach_reliability
            reliability: Default::default(),
        }
    }
}

/// Convenience: plan + run a network.
pub fn simulate_network(net: &Network, arch: &ArchConfig, sim: &SimConfig) -> RunStats {
    let plans = plan_network(net, arch, sim);
    let input_bytes = 32 * 32 * 3;
    Simulation::new(arch.clone(), sim.clone()).run(&plans, input_bytes)
}

/// Convenience with default input size and named config pair.
pub fn simulate(net: &Network, arch: ArchConfig, sim: SimConfig) -> RunStats {
    simulate_network(net, &arch, &sim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    /// CI smoke test: RunStats bookkeeping invariants on a full
    /// MobileNetV2 plan — per-layer stats must sum to the totals, the
    /// run must be non-trivial, and DDC must beat the `--baseline`
    /// configuration.
    #[test]
    fn run_stats_invariants_on_mobilenet_plan() {
        let net = zoo::mobilenet_v2();
        let ddc = simulate_network(&net, &ArchConfig::ddc_pim(), &SimConfig::ddc_full());
        assert!(ddc.total_cycles > 0, "empty simulation");
        assert!(!ddc.layers.is_empty());
        assert_eq!(ddc.layers.len(), net.layers.len());
        // per-layer stats sum to run totals
        let cycle_sum: u64 = ddc.layers.iter().map(|l| l.cycles).sum();
        assert_eq!(cycle_sum, ddc.total_cycles, "layer cycles != total");
        let mac_sum: u64 = ddc.layers.iter().map(|l| l.macs).sum();
        assert_eq!(mac_sum, ddc.total_macs, "layer MACs != total");
        let dram_sum: u64 = ddc.layers.iter().map(|l| l.dram_bytes).sum();
        // totals include the input image stream on top of layer weights
        assert!(ddc.total_dram_bytes >= dram_sum, "DRAM accounting shrank");
        assert!(ddc.total_dram_bytes - dram_sum <= 32 * 32 * 3);
        let energy_sum: f64 = ddc.layers.iter().map(|l| l.energy_mj).sum();
        assert!((energy_sum - ddc.total_energy_mj).abs() < 1e-9);
        // each layer's cycle decomposition is internally consistent
        for l in &ddc.layers {
            assert!(
                l.cycles >= l.compute_cycles + l.load_cycles + l.exposed_dram_cycles,
                "{}: component cycles exceed layer total",
                l.name
            );
        }
        // DDC speedup over --baseline > 1 on the paper's flagship model
        let base = simulate_network(&net, &ArchConfig::baseline(), &SimConfig::baseline());
        let speedup = base.total_cycles as f64 / ddc.total_cycles as f64;
        assert!(speedup > 1.0, "DDC not faster than baseline: {speedup}");
        assert!(ddc.latency_ms() > 0.0 && ddc.achieved_gops() > 0.0);
    }

    #[test]
    fn ddc_faster_than_baseline_mobilenet() {
        let net = zoo::mobilenet_v2();
        let base = simulate_network(&net, &ArchConfig::baseline(), &SimConfig::baseline());
        let ddc = simulate_network(&net, &ArchConfig::ddc_pim(), &SimConfig::ddc_full());
        let speedup = base.total_cycles as f64 / ddc.total_cycles as f64;
        // paper Fig. 13: 2.841x — the shape target is 2.3..3.3
        assert!(speedup > 2.3 && speedup < 3.3, "speedup={speedup}");
    }

    #[test]
    fn efficientnet_speedup_slightly_lower() {
        // paper: 2.694x for EfficientNet-B0 < 2.841x for MobileNetV2
        // (5x5 dw layers can't use the reconfig doubling)
        let mnv2 = {
            let net = zoo::mobilenet_v2();
            let b = simulate_network(&net, &ArchConfig::baseline(), &SimConfig::baseline());
            let d = simulate_network(&net, &ArchConfig::ddc_pim(), &SimConfig::ddc_full());
            b.total_cycles as f64 / d.total_cycles as f64
        };
        let enb0 = {
            let net = zoo::efficientnet_b0();
            let b = simulate_network(&net, &ArchConfig::baseline(), &SimConfig::baseline());
            let d = simulate_network(&net, &ArchConfig::ddc_pim(), &SimConfig::ddc_full());
            b.total_cycles as f64 / d.total_cycles as f64
        };
        assert!(enb0 < mnv2, "enb0={enb0} mnv2={mnv2}");
        assert!(enb0 > 2.0, "enb0={enb0}");
    }

    #[test]
    fn capacity_observability_is_consistent() {
        let net = zoo::mobilenet_v2();
        let ddc = simulate_network(&net, &ArchConfig::ddc_pim(), &SimConfig::ddc_full());
        // hidden + exposed covers every transfer cycle exactly once
        let ratio = ddc.prefetch_overlap_ratio();
        assert!((0.0..=1.0).contains(&ratio), "ratio={ratio}");
        assert_eq!(
            ddc.exposed_stall_cycles(),
            ddc.layers.iter().map(|l| l.exposed_dram_cycles).sum::<u64>()
        );
        for l in &ddc.layers {
            if l.dram_bytes > 0 {
                assert!(l.reload_passes >= 1, "{}: no reload pass", l.name);
                assert!(l.weight_occupancy > 0.0);
            } else {
                assert_eq!(l.reload_passes, 0, "{}: weightless layer", l.name);
            }
        }
        // MobileNetV2 fits the paper's 256 KB weight memory layer by
        // layer: no layer needs more than one pass
        assert_eq!(ddc.total_weight_reloads(), 0);
        let peak = ddc.peak_weight_occupancy();
        assert!(peak > 0.0 && peak <= 1.0, "peak={peak}");
    }

    #[test]
    fn tiny_weight_memory_forces_reload_passes() {
        // shrink the weight memory below VGG's FC footprint: the same
        // plans now need multiple reload passes (and occupancy > 1.0)
        // while the cycle totals stay exactly what they were
        let net = zoo::vgg19();
        let arch = ArchConfig::ddc_pim();
        let full = simulate_network(&net, &arch, &SimConfig::ddc_full());
        let mut small = arch.clone();
        small.weight_mem_kb = 16;
        let squeezed = simulate_network(&net, &small, &SimConfig::ddc_full());
        assert!(squeezed.total_weight_reloads() > full.total_weight_reloads());
        assert!(squeezed.peak_weight_occupancy() > 1.0);
        // observability only: capacity does not change the cycle model
        assert_eq!(full.total_cycles, squeezed.total_cycles);
    }

    #[test]
    fn dw_dominates_baseline() {
        let net = zoo::mobilenet_v2();
        let base = simulate_network(&net, &ArchConfig::baseline(), &SimConfig::baseline());
        assert!(base.dw_fraction() > 0.5, "dw={}", base.dw_fraction());
    }

    #[test]
    fn latency_in_paper_ballpark() {
        // paper Fig. 12(a): 20.97 ms end-to-end MobileNetV2 (ImageNet-
        // scale inputs); our CIFAR-scale run must land well under that
        // but at a nonzero, plausible value.
        let net = zoo::mobilenet_v2();
        let ddc = simulate_network(&net, &ArchConfig::ddc_pim(), &SimConfig::ddc_full());
        let ms = ddc.latency_ms();
        assert!(ms > 0.1 && ms < 50.0, "latency={ms}ms");
    }

    #[test]
    fn batch_scales_compute() {
        let net = zoo::resnet18();
        let mut sim = SimConfig::ddc_full();
        sim.batch = 1;
        let one = simulate_network(&net, &ArchConfig::ddc_pim(), &sim);
        sim.batch = 4;
        let four = simulate_network(&net, &ArchConfig::ddc_pim(), &sim);
        assert!(four.total_cycles > 3 * one.total_cycles);
        assert_eq!(four.total_macs, 4 * one.total_macs);
    }

    #[test]
    fn dram_traffic_halved_by_fcc() {
        let net = zoo::vgg19();
        let base = simulate_network(&net, &ArchConfig::baseline(), &SimConfig::baseline());
        let ddc = simulate_network(&net, &ArchConfig::ddc_pim(), &SimConfig::ddc_full());
        // conv weights halve; FC (large in VGG) unchanged
        assert!(ddc.total_dram_bytes < base.total_dram_bytes);
        use crate::mapping::PlanKind;
        let conv_only_base: u64 = base
            .layers
            .iter()
            .filter(|l| l.fcc || matches!(l.kind, PlanKind::StdRegular | PlanKind::StdDouble))
            .map(|l| l.dram_bytes)
            .sum();
        assert!(conv_only_base > 0);
    }

    #[test]
    fn energy_positive_and_fcc_lower() {
        let net = zoo::mobilenet_v2();
        let base = simulate_network(&net, &ArchConfig::baseline(), &SimConfig::baseline());
        let ddc = simulate_network(&net, &ArchConfig::ddc_pim(), &SimConfig::ddc_full());
        assert!(base.total_energy_mj > 0.0);
        // DDC moves less DRAM data and spends less MAC energy
        assert!(ddc.total_energy_mj < base.total_energy_mj);
    }
}
