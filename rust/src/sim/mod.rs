//! Cycle-accurate simulation engine.
//!
//! Consumes the mapper's [`crate::mapping::LayerPlan`]s (or the assembled
//! ISA stream) and produces per-layer and end-to-end cycle/energy
//! statistics, modelling the DRAM prefetch overlap the paper describes
//! (§III-D: next-layer weights stream in behind the current layer's
//! compute).

pub mod engine;
pub mod stats;

pub use engine::{simulate, simulate_network, Simulation};
pub use stats::{LayerStats, RunStats};
