//! Simulation statistics containers: per-layer and whole-run cycle /
//! traffic / energy outcomes, plus the capacity-pressure view (reload
//! passes, weight-memory occupancy, prefetch-overlap ratio, exposed
//! stalls) the reports and the streaming bench surface.

use crate::mapping::PlanKind;
use crate::metrics::ReliabilityStats;

/// Per-layer simulation outcome.
#[derive(Debug, Clone)]
pub struct LayerStats {
    pub name: String,
    pub kind: PlanKind,
    /// Cycles the layer occupied the fabric (incl. exposed DRAM stalls).
    pub cycles: u64,
    pub compute_cycles: u64,
    pub load_cycles: u64,
    pub exposed_dram_cycles: u64,
    pub macs: u64,
    pub dram_bytes: u64,
    pub sram_bytes: u64,
    pub energy_mj: f64,
    pub fcc: bool,
    /// Weight-reload passes this layer's weights need through the
    /// weight memory: 1 when they fit the capacity, `ceil(bytes /
    /// capacity)` when a single layer exceeds it, 0 for weightless
    /// layers (pooling).
    pub reload_passes: u64,
    /// Weight-memory occupancy demand of this layer (`weight bytes /
    /// capacity`, *not* clamped — > 1.0 flags a layer the memory
    /// cannot hold at once).
    pub weight_occupancy: f64,
}

/// Whole-run outcome.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    pub layers: Vec<LayerStats>,
    pub total_cycles: u64,
    pub total_macs: u64,
    pub total_dram_bytes: u64,
    pub total_energy_mj: f64,
    pub freq_mhz: f64,
    /// DRAM transfer cycles masked behind compute by the layer-ahead
    /// prefetch (the hidden half; the exposed half is the per-layer
    /// `exposed_dram_cycles` sum).
    pub hidden_dram_cycles: u64,
    /// Reliability counters of the functional session the run rode on
    /// (faults injected/detected/repaired, quarantined rows, fail-soft
    /// events).  The cycle engine itself books nothing here — it models
    /// a fault-free datapath — so this stays
    /// [`ReliabilityStats::default`] until a caller attaches the
    /// serving-side tally via [`RunStats::attach_reliability`], the
    /// same way the capacity-pressure view pairs the modelled
    /// reload/occupancy numbers with the session's measured counters.
    pub reliability: ReliabilityStats,
}

impl RunStats {
    pub fn latency_ms(&self) -> f64 {
        self.total_cycles as f64 / (self.freq_mhz * 1e3)
    }

    /// Achieved GOPS (2 ops per MAC).
    pub fn achieved_gops(&self) -> f64 {
        if self.total_cycles == 0 {
            return 0.0;
        }
        2.0 * self.total_macs as f64 / (self.total_cycles as f64 / (self.freq_mhz * 1e6)) / 1e9
    }

    /// Achieved TOPS/W over the run.
    pub fn achieved_tops_per_w(&self) -> f64 {
        if self.total_energy_mj <= 0.0 {
            return 0.0;
        }
        let ops = 2.0 * self.total_macs as f64;
        let joules = self.total_energy_mj * 1e-3;
        ops / joules / 1e12
    }

    /// Cycles spent in layers matching a predicate.
    pub fn cycles_where(&self, pred: impl Fn(&LayerStats) -> bool) -> u64 {
        self.layers.iter().filter(|l| pred(l)).map(|l| l.cycles).sum()
    }

    /// Latency fraction of depthwise layers (the paper's bottleneck
    /// analysis).
    pub fn dw_fraction(&self) -> f64 {
        let dw = self.cycles_where(|l| {
            matches!(
                l.kind,
                PlanKind::DwRegular | PlanKind::DwDbis | PlanKind::DwReconfig
            )
        });
        dw as f64 / self.total_cycles.max(1) as f64
    }

    /// Modelled latency when the conv layers are sharded across a
    /// `tiles`-tile macro-grid (see [`crate::arch::grid::MacroGrid`]):
    /// conv-layer cycles scale by `1/tiles` (each tile executes a
    /// balanced disjoint shard of the output volume concurrently),
    /// while FC and post-processing stay single-macro — an Amdahl-style
    /// first-order model, deliberately ignoring halo recompute and
    /// mesh traffic.  `tiles <= 1` returns [`RunStats::latency_ms`].
    pub fn grid_scaled_latency_ms(&self, tiles: usize) -> f64 {
        if tiles <= 1 {
            return self.latency_ms();
        }
        let conv = self.cycles_where(|l| {
            !matches!(l.kind, PlanKind::Fc | PlanKind::PostProcess)
        });
        let serial = self.total_cycles - conv;
        let scaled = serial + conv.div_ceil(tiles as u64);
        scaled as f64 / (self.freq_mhz * 1e3)
    }

    /// MVM-only latency (paper Fig. 12(a) reports 18.02 of 20.97 ms).
    pub fn mvm_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.compute_cycles).sum()
    }

    /// Total DRAM stall cycles the prefetch could not hide (sum of the
    /// per-layer exposed cycles).
    pub fn exposed_stall_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.exposed_dram_cycles).sum()
    }

    /// Fraction of all DRAM transfer cycles masked behind compute
    /// (0..=1); 1.0 when no transfer cycle was ever exposed.
    pub fn prefetch_overlap_ratio(&self) -> f64 {
        let exposed = self.exposed_stall_cycles();
        let total = self.hidden_dram_cycles + exposed;
        if total == 0 {
            return 1.0;
        }
        self.hidden_dram_cycles as f64 / total as f64
    }

    /// Weight-reload passes beyond each layer's first residency — the
    /// extra DRAM trips capacity pressure forces (0 when every layer
    /// fits the weight memory in one pass).
    pub fn total_weight_reloads(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| l.reload_passes.saturating_sub(1))
            .sum()
    }

    /// Peak per-layer weight-memory occupancy demand over the run
    /// (> 1.0 means some layer exceeds the capacity outright).
    pub fn peak_weight_occupancy(&self) -> f64 {
        self.layers
            .iter()
            .map(|l| l.weight_occupancy)
            .fold(0.0, f64::max)
    }

    /// Attach the functional session's reliability tally to this run
    /// (builder-style, used by the selfcheck / serve report paths).
    pub fn attach_reliability(mut self, r: ReliabilityStats) -> RunStats {
        self.reliability = r;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(cycles: u64, macs: u64) -> RunStats {
        RunStats {
            layers: vec![],
            total_cycles: cycles,
            total_macs: macs,
            total_dram_bytes: 0,
            total_energy_mj: 1e-3,
            freq_mhz: 333.0,
            hidden_dram_cycles: 0,
            reliability: ReliabilityStats::default(),
        }
    }

    #[test]
    fn attach_reliability_carries_the_tally() {
        let r = ReliabilityStats {
            faults_detected: 3,
            ..Default::default()
        };
        let s = stats(1, 1).attach_reliability(r);
        assert_eq!(s.reliability.faults_detected, 3);
        assert!(!s.reliability.is_quiet());
        // a fresh run is quiet until a session tally is attached
        assert!(stats(1, 1).reliability.is_quiet());
    }

    #[test]
    fn latency_at_333mhz() {
        let s = stats(333_000, 0);
        assert!((s.latency_ms() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn gops_math() {
        // 64 MACs/cycle at 333 MHz = 42.6 GOPS
        let s = stats(1_000_000, 64_000_000);
        assert!((s.achieved_gops() - 42.624).abs() < 0.01);
    }

    #[test]
    fn tops_per_w() {
        let s = stats(1, 500_000); // 1e6 ops over 1e-6 J = 1 TOPS/W... scaled
        assert!(s.achieved_tops_per_w() > 0.0);
    }

    fn layer(exposed: u64, passes: u64, occ: f64) -> LayerStats {
        LayerStats {
            name: "l".into(),
            kind: PlanKind::StdDouble,
            cycles: 100,
            compute_cycles: 90,
            load_cycles: 5,
            exposed_dram_cycles: exposed,
            macs: 1,
            dram_bytes: 1,
            sram_bytes: 1,
            energy_mj: 0.0,
            fcc: true,
            reload_passes: passes,
            weight_occupancy: occ,
        }
    }

    #[test]
    fn capacity_pressure_views() {
        let mut s = stats(200, 2);
        s.layers = vec![layer(0, 1, 0.5), layer(30, 3, 1.5)];
        s.hidden_dram_cycles = 90;
        assert_eq!(s.exposed_stall_cycles(), 30);
        assert!((s.prefetch_overlap_ratio() - 0.75).abs() < 1e-12);
        // reloads = passes beyond the first residency: (1-1) + (3-1)
        assert_eq!(s.total_weight_reloads(), 2);
        assert!((s.peak_weight_occupancy() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn grid_scaling_divides_conv_cycles_only() {
        let mut s = stats(400, 0);
        s.layers = vec![layer(0, 1, 0.5), layer(0, 1, 0.5)]; // 200 conv cycles
        let mut fc = layer(0, 1, 0.1);
        fc.kind = PlanKind::Fc;
        fc.cycles = 200;
        s.layers.push(fc);
        // 1 tile: unchanged; 4 tiles: 200 serial + 200/4 conv = 250
        assert!((s.grid_scaled_latency_ms(1) - s.latency_ms()).abs() < 1e-12);
        let scaled = s.grid_scaled_latency_ms(4);
        assert!((scaled - 250.0 / (333.0 * 1e3)).abs() < 1e-12);
        assert!(scaled < s.latency_ms());
    }

    #[test]
    fn quiet_run_has_full_overlap() {
        let s = stats(10, 1);
        assert_eq!(s.exposed_stall_cycles(), 0);
        assert_eq!(s.prefetch_overlap_ratio(), 1.0);
        assert_eq!(s.total_weight_reloads(), 0);
        assert_eq!(s.peak_weight_occupancy(), 0.0);
    }
}
