//! Simulation statistics containers.

use crate::mapping::PlanKind;

/// Per-layer simulation outcome.
#[derive(Debug, Clone)]
pub struct LayerStats {
    pub name: String,
    pub kind: PlanKind,
    /// Cycles the layer occupied the fabric (incl. exposed DRAM stalls).
    pub cycles: u64,
    pub compute_cycles: u64,
    pub load_cycles: u64,
    pub exposed_dram_cycles: u64,
    pub macs: u64,
    pub dram_bytes: u64,
    pub sram_bytes: u64,
    pub energy_mj: f64,
    pub fcc: bool,
}

/// Whole-run outcome.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    pub layers: Vec<LayerStats>,
    pub total_cycles: u64,
    pub total_macs: u64,
    pub total_dram_bytes: u64,
    pub total_energy_mj: f64,
    pub freq_mhz: f64,
}

impl RunStats {
    pub fn latency_ms(&self) -> f64 {
        self.total_cycles as f64 / (self.freq_mhz * 1e3)
    }

    /// Achieved GOPS (2 ops per MAC).
    pub fn achieved_gops(&self) -> f64 {
        if self.total_cycles == 0 {
            return 0.0;
        }
        2.0 * self.total_macs as f64 / (self.total_cycles as f64 / (self.freq_mhz * 1e6)) / 1e9
    }

    /// Achieved TOPS/W over the run.
    pub fn achieved_tops_per_w(&self) -> f64 {
        if self.total_energy_mj <= 0.0 {
            return 0.0;
        }
        let ops = 2.0 * self.total_macs as f64;
        let joules = self.total_energy_mj * 1e-3;
        ops / joules / 1e12
    }

    /// Cycles spent in layers matching a predicate.
    pub fn cycles_where(&self, pred: impl Fn(&LayerStats) -> bool) -> u64 {
        self.layers.iter().filter(|l| pred(l)).map(|l| l.cycles).sum()
    }

    /// Latency fraction of depthwise layers (the paper's bottleneck
    /// analysis).
    pub fn dw_fraction(&self) -> f64 {
        let dw = self.cycles_where(|l| {
            matches!(
                l.kind,
                PlanKind::DwRegular | PlanKind::DwDbis | PlanKind::DwReconfig
            )
        });
        dw as f64 / self.total_cycles.max(1) as f64
    }

    /// MVM-only latency (paper Fig. 12(a) reports 18.02 of 20.97 ms).
    pub fn mvm_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.compute_cycles).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(cycles: u64, macs: u64) -> RunStats {
        RunStats {
            layers: vec![],
            total_cycles: cycles,
            total_macs: macs,
            total_dram_bytes: 0,
            total_energy_mj: 1e-3,
            freq_mhz: 333.0,
        }
    }

    #[test]
    fn latency_at_333mhz() {
        let s = stats(333_000, 0);
        assert!((s.latency_ms() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn gops_math() {
        // 64 MACs/cycle at 333 MHz = 42.6 GOPS
        let s = stats(1_000_000, 64_000_000);
        assert!((s.achieved_gops() - 42.624).abs() < 0.01);
    }

    #[test]
    fn tops_per_w() {
        let s = stats(1, 500_000); // 1e6 ops over 1e-6 J = 1 TOPS/W... scaled
        assert!(s.achieved_tops_per_w() > 0.0);
    }
}
