//! Tiny benchmark harness (offline substitute for criterion).
//!
//! `cargo bench` targets are plain `harness = false` binaries that call
//! [`bench`]: warmup, then timed iterations with mean / min / max and
//! iterations-per-second, printed in a stable, grep-friendly format.
//!
//! [`BenchSession`] wraps the same primitives with the bench binaries'
//! shared CLI (`--json <path>` persists a `BENCH_*.json` artifact,
//! `--quick` scales iteration counts down for CI smoke runs) so the
//! repo's bench trajectory is machine-readable.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Instant;

use super::json::{to_string, Json};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

/// Time `f` over `iters` iterations (after `warmup` untimed runs).
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().copied().fold(f64::MAX, f64::min);
    let max = samples.iter().copied().fold(f64::MIN, f64::max);
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        min_ns: min,
        max_ns: max,
    };
    println!(
        "bench {:<48} {:>12.1} ns/iter (min {:>10.1}, max {:>10.1}, {:>10.2}/s, n={})",
        r.name,
        r.mean_ns,
        r.min_ns,
        r.max_ns,
        r.per_sec(),
        r.iters
    );
    r
}

/// Report a derived scalar (speedups, ratios) in the bench output.
pub fn report(name: &str, value: f64, unit: &str) {
    println!("value {name:<48} {value:>12.4} {unit}");
}

/// A recording wrapper over [`bench`]/[`report`] with the bench
/// binaries' shared CLI.  Create with [`BenchSession::from_env`], run
/// cases through [`BenchSession::bench`], then call
/// [`BenchSession::finish`] to write the JSON artifact (if `--json
/// <path>` was given).
pub struct BenchSession {
    name: String,
    json_path: Option<PathBuf>,
    quick: bool,
    results: Vec<BenchResult>,
    values: Vec<(String, f64, String)>,
}

impl BenchSession {
    /// Parse `--json <path>` / `--quick` from the process arguments.
    pub fn from_env(name: &str) -> Self {
        Self::from_args(name, std::env::args().skip(1))
    }

    pub fn from_args<I: IntoIterator<Item = String>>(name: &str, args: I) -> Self {
        let args: Vec<String> = args.into_iter().collect();
        let mut json_path = None;
        let mut quick = false;
        let mut i = 0;
        while i < args.len() {
            match args[i].as_str() {
                "--json" => {
                    i += 1;
                    let p = args.get(i).expect("--json needs a path argument");
                    json_path = Some(PathBuf::from(p.as_str()));
                }
                "--quick" => quick = true,
                // `cargo bench` appends `--bench` to harness=false
                // targets; accept and ignore it (as criterion does)
                "--bench" => {}
                other => panic!("unknown bench flag {other:?} (expected --json <path> / --quick)"),
            }
            i += 1;
        }
        BenchSession {
            name: name.to_string(),
            json_path,
            quick,
            results: Vec::new(),
            values: Vec::new(),
        }
    }

    /// `--quick` smoke mode (tiny iteration counts, timings untrusted).
    pub fn quick(&self) -> bool {
        self.quick
    }

    /// Scale a full iteration count down for `--quick` runs.
    pub fn iters(&self, full: u32) -> u32 {
        if self.quick {
            (full / 100).max(1)
        } else {
            full
        }
    }

    /// Run and record one benchmark case (`iters` is the full count;
    /// `--quick` scaling is applied here).
    pub fn bench<F: FnMut()>(&mut self, name: &str, warmup: u32, iters: u32, f: F) -> BenchResult {
        let warmup = if self.quick { warmup.min(1) } else { warmup };
        let r = bench(name, warmup, self.iters(iters), f);
        self.results.push(r.clone());
        r
    }

    /// Record a derived scalar alongside the timings.
    pub fn report(&mut self, name: &str, value: f64, unit: &str) {
        report(name, value, unit);
        self.values.push((name.to_string(), value, unit.to_string()));
    }

    /// Render the session as a JSON document (`ddc-pim-bench-v1`).
    pub fn to_json(&self) -> Json {
        let mut results = BTreeMap::new();
        for r in &self.results {
            let mut m = BTreeMap::new();
            m.insert("mean_ns".to_string(), Json::Num(r.mean_ns));
            m.insert("min_ns".to_string(), Json::Num(r.min_ns));
            m.insert("max_ns".to_string(), Json::Num(r.max_ns));
            m.insert("iters".to_string(), Json::Num(r.iters as f64));
            results.insert(r.name.clone(), Json::Obj(m));
        }
        let mut values = BTreeMap::new();
        for (name, value, unit) in &self.values {
            let mut m = BTreeMap::new();
            m.insert("value".to_string(), Json::Num(*value));
            m.insert("unit".to_string(), Json::Str(unit.clone()));
            values.insert(name.clone(), Json::Obj(m));
        }
        let mut top = BTreeMap::new();
        top.insert("schema".to_string(), Json::Str("ddc-pim-bench-v1".to_string()));
        top.insert("bench".to_string(), Json::Str(self.name.clone()));
        top.insert("quick".to_string(), Json::Bool(self.quick));
        top.insert("results".to_string(), Json::Obj(results));
        top.insert("values".to_string(), Json::Obj(values));
        Json::Obj(top)
    }

    /// Write the JSON artifact if `--json` was given; call last.
    pub fn finish(&self) {
        if let Some(path) = &self.json_path {
            let doc = to_string(&self.to_json()) + "\n";
            std::fs::write(path, doc)
                .unwrap_or_else(|e| panic!("writing bench json {}: {e}", path.display()));
            println!("wrote {}", path.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let r = bench("noop", 1, 10, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.iters, 10);
        assert!(r.min_ns <= r.mean_ns && r.mean_ns <= r.max_ns);
        assert!(r.per_sec() > 0.0);
    }

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn session_parses_flags() {
        let s = BenchSession::from_args("t", args(&[]));
        assert!(!s.quick());
        assert_eq!(s.iters(2000), 2000);
        let s = BenchSession::from_args("t", args(&["--quick", "--json", "out.json"]));
        assert!(s.quick());
        assert_eq!(s.iters(2000), 20);
        assert_eq!(s.iters(50), 1); // never scales to zero
        assert_eq!(s.json_path.as_deref(), Some(std::path::Path::new("out.json")));
        // `cargo bench` always appends --bench to harness=false targets
        let s = BenchSession::from_args("t", args(&["--bench", "--json", "b.json"]));
        assert!(!s.quick());
        assert!(s.json_path.is_some());
    }

    #[test]
    #[should_panic(expected = "unknown bench flag")]
    fn session_rejects_unknown_flags() {
        BenchSession::from_args("t", args(&["--frobnicate"]));
    }

    #[test]
    fn session_json_roundtrips() {
        let mut s = BenchSession::from_args("fabric", args(&["--quick"]));
        s.bench("case.a", 0, 100, || {
            std::hint::black_box(2 + 2);
        });
        s.report("case.a.speedup", 6.25, "x");
        let doc = to_string(&s.to_json());
        let v = Json::parse(&doc).unwrap();
        assert_eq!(v.get("schema").unwrap().as_str(), Some("ddc-pim-bench-v1"));
        assert_eq!(v.get("bench").unwrap().as_str(), Some("fabric"));
        assert_eq!(v.get("quick").unwrap().as_bool(), Some(true));
        let case = v.get("results").unwrap().get("case.a").unwrap();
        assert!(case.get("mean_ns").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(case.get("iters").unwrap().as_i64(), Some(1)); // 100/100
        let val = v.get("values").unwrap().get("case.a.speedup").unwrap();
        assert_eq!(val.get("value").unwrap().as_f64(), Some(6.25));
        assert_eq!(val.get("unit").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn session_finish_writes_file() {
        let path = std::env::temp_dir().join("ddc_pim_benchkit_test.json");
        let path_s = path.to_string_lossy().to_string();
        let mut s = BenchSession::from_args("t", args(&["--json", &path_s, "--quick"]));
        s.bench("w", 0, 100, || {
            std::hint::black_box(1);
        });
        s.finish();
        let body = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let v = Json::parse(body.trim()).unwrap();
        assert!(v.get("results").unwrap().get("w").is_some());
    }
}
