//! Tiny benchmark harness (offline substitute for criterion).
//!
//! `cargo bench` targets are plain `harness = false` binaries that call
//! [`bench`]: warmup, then timed iterations with mean / min / max and
//! iterations-per-second, printed in a stable, grep-friendly format.

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

/// Time `f` over `iters` iterations (after `warmup` untimed runs).
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().copied().fold(f64::MAX, f64::min);
    let max = samples.iter().copied().fold(f64::MIN, f64::max);
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        min_ns: min,
        max_ns: max,
    };
    println!(
        "bench {:<48} {:>12.1} ns/iter (min {:>10.1}, max {:>10.1}, {:>10.2}/s, n={})",
        r.name,
        r.mean_ns,
        r.min_ns,
        r.max_ns,
        r.per_sec(),
        r.iters
    );
    r
}

/// Report a derived scalar (speedups, ratios) in the bench output.
pub fn report(name: &str, value: f64, unit: &str) {
    println!("value {name:<48} {value:>12.4} {unit}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let r = bench("noop", 1, 10, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.iters, 10);
        assert!(r.min_ns <= r.mean_ns && r.mean_ns <= r.max_ns);
        assert!(r.per_sec() > 0.0);
    }
}
