//! Shared environment-knob resolution: the one warn-on-garbage contract
//! behind `DDC_THREADS`, `DDC_WORKERS` and `DDC_GRID`.
//!
//! Every runtime knob follows the same precedence: an explicit request
//! wins, an *unset* request falls back to an environment variable, and
//! an unparseable variable is **warned about on stderr and treated as
//! unset** — a typo must never be silently absorbed into a surprising
//! configuration.  Before this module the contract existed as three
//! hand-copies (`resolve_threads` / `resolve_workers` / `resolve_grid`);
//! now each resolver delegates here, so the warning text and the
//! fallback semantics can only drift together, visibly, in one place.

/// Resolve one environment knob: read `var`, parse it with `parse`, and
/// return the parsed value — or `default` (warning on stderr) when the
/// variable is set but unparseable, or `default` (silently) when it is
/// unset.  `default_desc` is the human-readable form of `default` used
/// in the warning (`"1"`, `"1x1"`, ...).
pub fn resolve_env_knob<T, F>(var: &str, default: T, default_desc: &str, parse: F) -> T
where
    F: Fn(&str) -> Result<T, String>,
{
    let raw = std::env::var(var).ok();
    let (value, warning) = knob_from_raw(var, raw.as_deref(), default, default_desc, parse);
    if let Some(msg) = warning {
        eprintln!("{msg}");
    }
    value
}

/// The pure core of [`resolve_env_knob`]: same contract, but the raw
/// variable value is injected and the warning is *returned* instead of
/// printed — so unit tests can pin the exact warning text without
/// mutating the live process environment (racy under the parallel test
/// harness).
pub fn knob_from_raw<T, F>(
    var: &str,
    raw: Option<&str>,
    default: T,
    default_desc: &str,
    parse: F,
) -> (T, Option<String>)
where
    F: Fn(&str) -> Result<T, String>,
{
    match raw {
        None => (default, None),
        Some(raw) => match parse(raw) {
            Ok(v) => (v, None),
            Err(e) => (
                default,
                Some(format!(
                    "[ddc-config] ignoring {var}={raw:?}: {e}; using {default_desc}"
                )),
            ),
        },
    }
}

/// Parse a positive integer knob value (`DDC_THREADS` / `DDC_WORKERS`).
/// Zero and garbage are both errors: `0` has no meaning as an explicit
/// width, and accepting it would silently disable the knob's consumer.
pub fn parse_positive(v: &str) -> Result<usize, String> {
    match v.trim().parse::<usize>() {
        Ok(n) if n >= 1 => Ok(n),
        _ => Err("want a positive integer".into()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unset_variable_is_a_silent_default() {
        let (v, warn) = knob_from_raw("DDC_THREADS", None, 1usize, "1", parse_positive);
        assert_eq!(v, 1);
        assert!(warn.is_none());
    }

    #[test]
    fn parseable_value_wins_without_warning() {
        let (v, warn) = knob_from_raw("DDC_THREADS", Some("4"), 1usize, "1", parse_positive);
        assert_eq!(v, 4);
        assert!(warn.is_none());
        // whitespace is the shell's problem, not the user's
        let (v, _) = knob_from_raw("DDC_WORKERS", Some(" 2 "), 1usize, "1", parse_positive);
        assert_eq!(v, 2);
    }

    #[test]
    fn garbage_warns_with_the_exact_contract_text() {
        let (v, warn) = knob_from_raw("DDC_THREADS", Some("lots"), 1usize, "1", parse_positive);
        assert_eq!(v, 1);
        assert_eq!(
            warn.as_deref(),
            Some("[ddc-config] ignoring DDC_THREADS=\"lots\": want a positive integer; using 1")
        );
    }

    #[test]
    fn zero_is_garbage_not_a_width() {
        let (v, warn) = knob_from_raw("DDC_WORKERS", Some("0"), 1usize, "1", parse_positive);
        assert_eq!(v, 1);
        assert_eq!(
            warn.as_deref(),
            Some("[ddc-config] ignoring DDC_WORKERS=\"0\": want a positive integer; using 1")
        );
    }

    #[test]
    fn parser_errors_flow_into_the_warning() {
        // a custom parser's message (e.g. GridShape's "bad grid shape
        // ...") lands verbatim between the prefix and the default
        let parse = |s: &str| -> Result<usize, String> {
            s.parse().map_err(|_| format!("bad value {s:?} (want RxC)"))
        };
        let (v, warn) = knob_from_raw("DDC_GRID", Some("bogus"), 7usize, "1x1", parse);
        assert_eq!(v, 7);
        assert_eq!(
            warn.as_deref(),
            Some("[ddc-config] ignoring DDC_GRID=\"bogus\": bad value \"bogus\" (want RxC); using 1x1")
        );
    }

    #[test]
    fn parse_positive_contract() {
        assert_eq!(parse_positive("4"), Ok(4));
        assert_eq!(parse_positive(" 2 "), Ok(2));
        assert!(parse_positive("0").is_err());
        assert!(parse_positive("-3").is_err());
        assert!(parse_positive("lots").is_err());
        assert!(parse_positive("").is_err());
    }
}
