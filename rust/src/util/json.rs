//! Minimal JSON parser/serializer (offline substrate for serde_json).
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null).  Numbers are kept as `f64`; the artifact
//! files we read (goldens.json, accuracy.json) only contain numbers,
//! strings, arrays and objects.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: src.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Flatten a numeric array into `Vec<f64>`.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(Json::as_f64).collect())
    }

    pub fn as_i64_vec(&self) -> Option<Vec<i64>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(Json::as_i64).collect())
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences verbatim
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = (start + len).min(self.bytes.len());
                        self.pos = end;
                        out.push_str(
                            std::str::from_utf8(&self.bytes[start..end])
                                .map_err(|_| self.err("bad utf8"))?,
                        );
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Serialize a [`Json`] value (compact form).
pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_json(v, &mut s);
    s
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\r' => out.push_str("\\r"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(&Json::Str(k.clone()), out);
                out.push(':');
                write_json(val, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"hi\\n\"").unwrap(),
            Json::Str("hi\n".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(0).unwrap().as_i64(), Some(1));
        assert_eq!(
            v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\"").unwrap(),
            Json::Str("A".to_string())
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"name":"x\"y","ok":true}"#;
        let v = Json::parse(src).unwrap();
        let back = Json::parse(&to_string(&v)).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("[1,").is_err());
    }

    #[test]
    fn big_numeric_array() {
        let src = format!(
            "[{}]",
            (0..1000).map(|i| i.to_string()).collect::<Vec<_>>().join(",")
        );
        let v = Json::parse(&src).unwrap();
        let nums = v.as_i64_vec().unwrap();
        assert_eq!(nums.len(), 1000);
        assert_eq!(nums[999], 999);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
