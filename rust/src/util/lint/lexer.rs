//! Minimal Rust tokenizer for `ddc-lint`.
//!
//! This is *not* a parser: it produces a flat token stream good enough
//! to ask lexical questions ("is there an ident `unwrap` followed by
//! `(`?", "what comment precedes this `unsafe`?") without ever
//! misreading a string literal or a comment as code.  The hard parts it
//! gets right, because the rules depend on them:
//!
//! - line/block comments (nested `/* /* */ */`), captured with their
//!   text so rules can look for `SAFETY:` and waiver markers;
//! - string/char literals, including raw strings `r#"..."#`, byte
//!   strings, and the `'a'`-vs-`'a` char/lifetime ambiguity;
//! - line numbers on every token, for findings.
//!
//! Everything else — numbers, idents, punctuation — is deliberately
//! coarse.  A token stream this shape is exactly what the existing
//! hand-audits grep for, made precise.

/// One lexed token with its 1-based source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Token {
    pub kind: TokenKind,
    pub line: usize,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TokenKind {
    /// Identifier or keyword (`unsafe`, `fn`, `unwrap`, ...).
    Ident(String),
    /// Integer or float literal (value kept as written).
    Number(String),
    /// String / char / byte-string literal (contents dropped).
    Literal,
    /// Lifetime (`'a`) — distinct from a char literal.
    Lifetime,
    /// Single punctuation byte: `{ } ( ) [ ] ; : , . # ! & * = < > ...`
    Punct(char),
    /// A comment, with its trimmed text (both `//` and `/* */`).
    Comment(String),
}

impl TokenKind {
    pub fn is_ident(&self, s: &str) -> bool {
        matches!(self, TokenKind::Ident(i) if i == s)
    }
    pub fn is_punct(&self, c: char) -> bool {
        matches!(self, TokenKind::Punct(p) if *p == c)
    }
}

/// Tokenize `src`.  Unterminated constructs (string, block comment) eat
/// to EOF rather than erroring: the lint runs on code rustc already
/// accepted, so graceful degradation beats a second error channel.
pub fn tokenize(src: &str) -> Vec<Token> {
    let b = src.as_bytes();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < b.len() {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
            }
            c if c.is_ascii_whitespace() => i += 1,
            b'/' if i + 1 < b.len() && b[i + 1] == b'/' => {
                let start = i + 2;
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
                let text = src[start..i].trim().to_string();
                toks.push(Token { kind: TokenKind::Comment(text), line });
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'*' => {
                let start_line = line;
                let start = i + 2;
                i += 2;
                let mut depth = 1usize;
                while i < b.len() && depth > 0 {
                    if b[i] == b'\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                let end = if i >= 2 { i - 2 } else { i };
                let text = src[start..end.max(start)].trim().to_string();
                toks.push(Token { kind: TokenKind::Comment(text), line: start_line });
            }
            b'"' => {
                i = skip_string(b, i, &mut line);
                toks.push(Token { kind: TokenKind::Literal, line });
            }
            b'r' | b'b' if starts_raw_or_byte_string(b, i) => {
                let start_line = line;
                i = skip_raw_or_byte_string(b, i, &mut line);
                toks.push(Token { kind: TokenKind::Literal, line: start_line });
            }
            b'\'' => {
                // char literal vs lifetime: a lifetime is ' + ident NOT
                // followed by a closing quote ('a, 'static); a char
                // literal always closes ('a', '\n', '\'')
                if is_char_literal(b, i) {
                    i = skip_char_literal(b, i);
                    toks.push(Token { kind: TokenKind::Literal, line });
                } else {
                    i += 1;
                    while i < b.len() && (b[i] == b'_' || b[i].is_ascii_alphanumeric()) {
                        i += 1;
                    }
                    toks.push(Token { kind: TokenKind::Lifetime, line });
                }
            }
            c if c == b'_' || c.is_ascii_alphabetic() => {
                let start = i;
                while i < b.len() && (b[i] == b'_' || b[i].is_ascii_alphanumeric()) {
                    i += 1;
                }
                toks.push(Token {
                    kind: TokenKind::Ident(src[start..i].to_string()),
                    line,
                });
            }
            c if c.is_ascii_digit() => {
                let start = i;
                // coarse: digits, underscores, hex/bin letters, one
                // dot, exponent — anything ident-ish glued to a digit
                while i < b.len()
                    && (b[i].is_ascii_alphanumeric() || b[i] == b'_' || b[i] == b'.')
                {
                    // `0..n` range: stop before the second dot
                    if b[i] == b'.' && i + 1 < b.len() && b[i + 1] == b'.' {
                        break;
                    }
                    i += 1;
                }
                toks.push(Token {
                    kind: TokenKind::Number(src[start..i].to_string()),
                    line,
                });
            }
            c => {
                toks.push(Token { kind: TokenKind::Punct(c as char), line });
                i += 1;
            }
        }
    }
    toks
}

/// Skip a `"..."` string starting at `b[i] == '"'`; returns the index
/// past the closing quote and bumps `line` across embedded newlines.
fn skip_string(b: &[u8], mut i: usize, line: &mut usize) -> usize {
    i += 1;
    while i < b.len() {
        match b[i] {
            b'\\' => i += 2,
            b'"' => return i + 1,
            b'\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// Does `b[i..]` start a raw string (`r"`, `r#"`), byte string (`b"`),
/// or raw byte string (`br"`, `br#"`)?  `b[i]` is `r` or `b`.
fn starts_raw_or_byte_string(b: &[u8], i: usize) -> bool {
    let rest = &b[i..];
    let after_b = if rest[0] == b'b' { &rest[1..] } else { rest };
    if rest[0] == b'b' && after_b.first() == Some(&b'"') {
        return true; // b"..."
    }
    let after_r = if after_b.first() == Some(&b'r') { &after_b[1..] } else { return false };
    let mut j = 0;
    while after_r.get(j) == Some(&b'#') {
        j += 1;
    }
    after_r.get(j) == Some(&b'"')
}

/// Skip the raw/byte string whose start `starts_raw_or_byte_string`
/// confirmed; returns the index past its end.
fn skip_raw_or_byte_string(b: &[u8], mut i: usize, line: &mut usize) -> usize {
    if b[i] == b'b' {
        i += 1;
    }
    if i < b.len() && b[i] == b'"' {
        return skip_string(b, i, line); // b"..." — escapes apply
    }
    i += 1; // the 'r'
    let mut hashes = 0usize;
    while i < b.len() && b[i] == b'#' {
        hashes += 1;
        i += 1;
    }
    i += 1; // opening quote
    while i < b.len() {
        if b[i] == b'\n' {
            *line += 1;
            i += 1;
        } else if b[i] == b'"' {
            // close only when the quote is followed by the full hash run
            let mut k = 0;
            while k < hashes && i + 1 + k < b.len() && b[i + 1 + k] == b'#' {
                k += 1;
            }
            if k == hashes {
                return i + 1 + hashes;
            }
            i += 1;
        } else {
            i += 1;
        }
    }
    i
}

/// Is `b[i] == '\''` the start of a char literal (vs a lifetime)?
fn is_char_literal(b: &[u8], i: usize) -> bool {
    match b.get(i + 1) {
        Some(b'\\') => true,                   // '\n', '\''
        Some(&c) if c == b'\'' => false,       // '' — not valid, treat as lifetime-ish
        Some(&c) if c == b'_' || c.is_ascii_alphanumeric() => {
            // 'a' is a char only if the next byte closes it; 'static
            // runs on as an ident
            b.get(i + 2) == Some(&b'\'')
        }
        Some(_) => true, // '(' etc. — punctuation chars close immediately
        None => false,
    }
}

/// Skip a char literal starting at `'`; returns the index past the
/// closing quote.
fn skip_char_literal(b: &[u8], mut i: usize) -> usize {
    i += 1;
    if i < b.len() && b[i] == b'\\' {
        i += 2;
    } else {
        i += 1;
    }
    while i < b.len() && b[i] != b'\'' {
        i += 1; // unicode escapes '\u{1F600}'
    }
    i + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        tokenize(src)
            .into_iter()
            .filter_map(|t| match t.kind {
                TokenKind::Ident(i) => Some(i),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn comments_and_strings_are_not_code() {
        let src = r##"
            // let x = foo.unwrap();
            /* also not.unwrap() here /* nested */ still comment */
            let s = "not.unwrap() either";
            let r = r#"raw "quoted" not.unwrap()"#;
            real.call();
        "##;
        let ids = idents(src);
        assert!(!ids.contains(&"unwrap".to_string()));
        assert!(ids.contains(&"real".to_string()));
        assert!(ids.contains(&"call".to_string()));
    }

    #[test]
    fn char_vs_lifetime() {
        let toks = tokenize("fn f<'a>(x: &'a str) { let c = 'x'; let n = '\\n'; }");
        let lifetimes = toks.iter().filter(|t| t.kind == TokenKind::Lifetime).count();
        let chars = toks.iter().filter(|t| t.kind == TokenKind::Literal).count();
        assert_eq!(lifetimes, 2);
        assert_eq!(chars, 2);
    }

    #[test]
    fn line_numbers_track_multiline_constructs() {
        let src = "a\n/* two\nlines */\nb";
        let toks = tokenize(src);
        assert_eq!(toks[0].line, 1); // a
        assert_eq!(toks[1].line, 2); // comment starts line 2
        assert_eq!(toks[2].line, 4); // b after the 2-line comment
    }

    #[test]
    fn comment_text_is_captured_for_safety_scan() {
        let toks = tokenize("// SAFETY: disjoint lanes\nunsafe { x() }");
        match &toks[0].kind {
            TokenKind::Comment(t) => assert!(t.starts_with("SAFETY:")),
            other => panic!("expected comment, got {other:?}"),
        }
        assert!(toks[1].kind.is_ident("unsafe"));
    }

    #[test]
    fn numbers_and_ranges() {
        let toks = tokenize("for i in 0..10 { a[3] = 1.5e3; }");
        let nums: Vec<_> = toks
            .iter()
            .filter_map(|t| match &t.kind {
                TokenKind::Number(n) => Some(n.clone()),
                _ => None,
            })
            .collect();
        assert_eq!(nums, vec!["0", "10", "3", "1.5e3"]);
    }

    #[test]
    fn byte_strings_skip_clean() {
        let ids = idents(r#"let b = b"bytes.unwrap()"; after();"#);
        assert!(!ids.contains(&"unwrap".to_string()));
        assert!(ids.contains(&"after".to_string()));
    }
}
