//! Parser for `lint-hotpaths.toml` — the checked-in manifest naming
//! which functions the manifest-scoped rules apply to.
//!
//! The workspace is offline (no `toml` crate), so this is a parser for
//! exactly the subset the manifest uses and nothing more:
//!
//! ```toml
//! # comment
//! [section]
//! "key" = ["a", "b"]
//! "other" = [
//!     "multi",
//!     "line",
//! ]
//! ```
//!
//! Sections understood by the lint: `[no_alloc]` and `[no_panic]`
//! (file path → list of function-name entries, `"*"` meaning the whole
//! file) and `[atomics]` (`"file::fn"` → list of allowed
//! `Ordering::*` variants).  Unknown sections are an error — a typoed
//! section silently enforcing nothing is exactly the failure mode this
//! tool exists to kill.

use std::collections::BTreeMap;

/// Parsed manifest: section name → (key → values), insertion-ordered
/// by key via BTreeMap for deterministic reporting.
#[derive(Debug, Default, Clone)]
pub struct Manifest {
    pub sections: BTreeMap<String, BTreeMap<String, Vec<String>>>,
}

impl Manifest {
    /// Look up a section, empty map if absent.
    pub fn section(&self, name: &str) -> BTreeMap<String, Vec<String>> {
        self.sections.get(name).cloned().unwrap_or_default()
    }
}

/// Known section names; anything else is a parse error.
const KNOWN_SECTIONS: &[&str] = &["no_alloc", "no_panic", "atomics"];

/// Parse manifest text.  Errors carry the 1-based line number.
pub fn parse(text: &str) -> Result<Manifest, String> {
    let mut m = Manifest::default();
    let mut current: Option<String> = None;
    let mut lines = text.lines().enumerate().peekable();
    while let Some((idx, raw)) = lines.next() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {lineno}: unterminated section header"))?
                .trim()
                .to_string();
            if !KNOWN_SECTIONS.contains(&name.as_str()) {
                return Err(format!(
                    "line {lineno}: unknown section [{name}] (known: {})",
                    KNOWN_SECTIONS.join(", ")
                ));
            }
            m.sections.entry(name.clone()).or_default();
            current = Some(name);
            continue;
        }
        let section = current
            .clone()
            .ok_or_else(|| format!("line {lineno}: entry before any [section] header"))?;
        let (key_part, val_part) = line
            .split_once('=')
            .ok_or_else(|| format!("line {lineno}: expected `\"key\" = [...]`"))?;
        let key = unquote(key_part.trim())
            .ok_or_else(|| format!("line {lineno}: key must be a quoted string"))?;
        // gather the value, consuming continuation lines until the
        // bracket closes
        let mut val = val_part.trim().to_string();
        while !val.ends_with(']') {
            let (cidx, craw) = lines
                .next()
                .ok_or_else(|| format!("line {lineno}: unterminated array for {key:?}"))?;
            let cont = strip_comment(craw).trim().to_string();
            if cont.is_empty() {
                continue;
            }
            let _ = cidx;
            val.push(' ');
            val.push_str(&cont);
        }
        let inner = val
            .strip_prefix('[')
            .and_then(|v| v.strip_suffix(']'))
            .ok_or_else(|| format!("line {lineno}: value must be an array"))?;
        let mut items = Vec::new();
        for piece in inner.split(',') {
            let piece = piece.trim();
            if piece.is_empty() {
                continue; // trailing comma
            }
            let item = unquote(piece)
                .ok_or_else(|| format!("line {lineno}: array item {piece:?} must be quoted"))?;
            items.push(item);
        }
        let sec = m.sections.entry(section).or_default();
        if sec.insert(key.clone(), items).is_some() {
            return Err(format!("line {lineno}: duplicate key {key:?}"));
        }
    }
    Ok(m)
}

/// Strip a `#` comment, respecting quotes (a `#` inside `"..."` is
/// content, not a comment).
fn strip_comment(line: &str) -> &str {
    let b = line.as_bytes();
    let mut in_str = false;
    for (i, &c) in b.iter().enumerate() {
        match c {
            b'"' => in_str = !in_str,
            b'#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// `"abc"` → `abc`; anything unquoted → None.
fn unquote(s: &str) -> Option<String> {
    s.strip_prefix('"')?.strip_suffix('"').map(str::to_string)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_manifest_shape() {
        let m = parse(
            r#"
# hot paths
[no_alloc]
"mapping/exec.rs" = ["execute", "execute_par"]
"arch/pim_macro.rs" = [
    "mvm_row_into",   # comment after item
    "pack_input_planes",
]

[no_panic]
"coordinator/service.rs" = ["*"]

[atomics]
"util/pool.rs::pop" = ["Acquire", "AcqRel"]
"#,
        )
        .expect("parse");
        assert_eq!(
            m.section("no_alloc")["mapping/exec.rs"],
            vec!["execute", "execute_par"]
        );
        assert_eq!(
            m.section("no_alloc")["arch/pim_macro.rs"],
            vec!["mvm_row_into", "pack_input_planes"]
        );
        assert_eq!(m.section("no_panic")["coordinator/service.rs"], vec!["*"]);
        assert_eq!(
            m.section("atomics")["util/pool.rs::pop"],
            vec!["Acquire", "AcqRel"]
        );
    }

    #[test]
    fn unknown_section_is_an_error() {
        let err = parse("[no_allocs]\n").unwrap_err();
        assert!(err.contains("unknown section"), "{err}");
    }

    #[test]
    fn entry_before_section_is_an_error() {
        let err = parse("\"a\" = [\"b\"]\n").unwrap_err();
        assert!(err.contains("before any"), "{err}");
    }

    #[test]
    fn duplicate_key_is_an_error() {
        let err = parse("[no_panic]\n\"a.rs\" = [\"*\"]\n\"a.rs\" = [\"f\"]\n").unwrap_err();
        assert!(err.contains("duplicate key"), "{err}");
    }

    #[test]
    fn unquoted_items_are_an_error() {
        let err = parse("[no_panic]\n\"a.rs\" = [f]\n").unwrap_err();
        assert!(err.contains("must be quoted"), "{err}");
    }
}
