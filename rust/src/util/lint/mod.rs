//! `ddc-lint`: the repo-invariant static analysis pass.
//!
//! Eight PRs of "verified by review + mechanical greps" turned into a
//! checked-in tool: a hand-rolled lexer ([`lexer`]), a TOML-subset
//! manifest reader ([`manifest`]) for `lint-hotpaths.toml`, the five
//! invariant rules ([`rules`]), and a deterministic-interleaving
//! checker ([`shuttle`]) that model-checks the two lock-free protocols
//! the static rules can't see into.  The `ddc-lint` binary
//! (`src/bin/ddc_lint.rs`) drives all of it in CI; DESIGN.md §11 and
//! `docs/linting.md` are the operator story.

pub mod lexer;
pub mod manifest;
pub mod rules;
pub mod shuttle;

pub use rules::{lint_source, Finding};

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Effective lint configuration: the built-in allowlists plus the three
/// manifest tables.  File names are relative to `rust/src` with `/`
/// separators (`"util/pool.rs"`).
#[derive(Debug, Clone, Default)]
pub struct Config {
    /// Files allowed to call the cell/plane mutators (the arch write
    /// path that keeps FCC coherence, sparsity summaries and the fault
    /// ledger in sync).
    pub write_path_allow: Vec<String>,
    /// Files allowed to contain `unsafe` at all.
    pub unsafe_allow: Vec<String>,
    /// `[no_alloc]`: file → hot function names (zero-alloc contract).
    pub no_alloc: BTreeMap<String, Vec<String>>,
    /// `[no_panic]`: file → function names (`"*"` = whole file).
    pub no_panic: BTreeMap<String, Vec<String>>,
    /// `[atomics]`: `"file::fn"` → allowed `Ordering` variants.
    pub atomics: BTreeMap<String, Vec<String>>,
    /// Files whose `Ordering::*` uses are audited against `atomics`.
    pub atomics_files: Vec<String>,
}

impl Config {
    /// The repo's fixed allowlists married to a parsed manifest.  The
    /// allowlists are code, not manifest entries, on purpose: widening
    /// *where unsafe may live* or *what may write cells* should be a
    /// reviewed source change, not a config tweak.
    pub fn from_manifest(man: &manifest::Manifest) -> Config {
        Config {
            write_path_allow: vec![
                "arch/sram.rs".into(),
                "arch/pim_core.rs".into(),
                "arch/compartment.rs".into(),
                "arch/dbmu.rs".into(),
            ],
            unsafe_allow: vec![
                "util/pool.rs".into(),
                "mapping/exec.rs".into(),
                "runtime/reference.rs".into(),
            ],
            no_alloc: man.section("no_alloc"),
            no_panic: man.section("no_panic"),
            atomics: man.section("atomics"),
            atomics_files: vec![
                "util/pool.rs".into(),
                "coordinator/service.rs".into(),
                "metrics.rs".into(),
            ],
        }
    }
}

/// Lint every `.rs` file under `src_root` (recursively, deterministic
/// order).  Returns all findings; I/O problems are findings too (rule
/// `io`), so a vanished file can't silently pass.
pub fn lint_tree(src_root: &Path, cfg: &Config) -> Vec<Finding> {
    let mut files = Vec::new();
    collect_rs_files(src_root, &mut files);
    files.sort();
    let mut findings = Vec::new();
    for path in files {
        let rel = path
            .strip_prefix(src_root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        match std::fs::read_to_string(&path) {
            Ok(src) => findings.extend(lint_source(&rel, &src, cfg)),
            Err(e) => findings.push(Finding {
                file: rel,
                line: 0,
                rule: "io",
                message: format!("unreadable: {e}"),
            }),
        }
    }
    findings
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// The violation fixtures: file stem → (rel-name the file is linted
/// *as*, rule it must trigger).  Fixtures pose as in-scope files so
/// each exercises exactly one rule against the real repo config.
pub const FIXTURE_EXPECTATIONS: &[(&str, &str, &str)] = &[
    ("write_path", "mapping/rogue.rs", "write_path"),
    ("unsafe_module", "model/rogue.rs", "unsafe_module"),
    ("unsafe_no_safety", "mapping/exec.rs", "unsafe_safety"),
    ("no_panic", "coordinator/service.rs", "no_panic"),
    ("hot_alloc", "mapping/exec.rs", "hot_alloc"),
    ("atomics", "util/pool.rs", "atomics"),
    ("waiver", "coordinator/service.rs", "waiver"),
];

/// Self-check: every fixture under `fixtures_dir` must produce at
/// least one finding, and *only* findings of its expected rule.  This
/// is the lint linting itself — a rule that stops firing turns the
/// suite red, not silent.
pub fn self_check(fixtures_dir: &Path, cfg: &Config) -> Result<(), String> {
    for (stem, rel_as, rule) in FIXTURE_EXPECTATIONS {
        let path = fixtures_dir.join(format!("{stem}.rs"));
        let src = std::fs::read_to_string(&path)
            .map_err(|e| format!("fixture {}: {e}", path.display()))?;
        let findings = lint_source(rel_as, &src, cfg);
        if findings.is_empty() {
            return Err(format!(
                "fixture {stem}.rs: expected a `{rule}` finding, got none — rule is dead"
            ));
        }
        if let Some(f) = findings.iter().find(|f| f.rule != *rule) {
            return Err(format!(
                "fixture {stem}.rs: expected only `{rule}` findings, got: {f}"
            ));
        }
    }
    Ok(())
}
