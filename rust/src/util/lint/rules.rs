//! The five `ddc-lint` rules, evaluated over one file's token stream.
//!
//! | rule            | invariant                                              |
//! |-----------------|--------------------------------------------------------|
//! | `write_path`    | cell/plane mutators called only in the arch write path |
//! | `unsafe_module` | `unsafe` only in allowlisted modules                   |
//! | `unsafe_safety` | every `unsafe` carries a nearby `// SAFETY:` comment   |
//! | `no_panic`      | no unwrap/expect/panic!/literal-index in serving scope |
//! | `hot_alloc`     | no allocating calls in manifest-named hot functions    |
//! | `atomics`       | every `Ordering::*` matches the documented protocol    |
//! | `waiver`        | a waiver comment must state a reason                   |
//!
//! Scope control: `#[cfg(test)]` / `#[test]` items are skipped
//! entirely, and any finding can be waived with
//! `// ddc-lint: allow(<rule>) — <reason>` on the same line or within
//! the three lines above.  A waiver with no reason is itself flagged —
//! unexplained suppressions rot.

use super::lexer::{tokenize, Token, TokenKind};
use super::Config;

/// One lint finding.  `rule` is the machine name from the table above.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Cell/plane mutators that must stay on the single write path: calls
/// to these outside [`Config::write_path_allow`] break FCC complement
/// coherence, the sparsity summaries, or the fault intent ledger.
const WRITE_PATH_MUTATORS: &[&str] = &["write_weight8", "write_row"];

/// Allocating calls banned inside manifest-named hot functions.
const HOT_ALLOC_METHODS: &[&str] = &["push", "to_vec", "clone", "collect"];

/// Macros that abort the serving path.
const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

/// Lint one file's source text.  `rel` is the path relative to
/// `rust/src` with `/` separators (`"util/pool.rs"`): every allowlist
/// and manifest key is expressed in that namespace.
pub fn lint_source(rel: &str, src: &str, cfg: &Config) -> Vec<Finding> {
    let toks = tokenize(src);
    let ctx = FileContext::build(&toks);
    let waivers = collect_waivers(&toks);
    let mut findings = Vec::new();

    // waiver hygiene first: a reasonless waiver is a finding even if
    // it never matches anything
    for w in &waivers {
        if !w.has_reason {
            findings.push(Finding {
                file: rel.to_string(),
                line: w.line,
                rule: "waiver",
                message: format!(
                    "waiver for `{}` has no reason — write `ddc-lint: allow({}) — <why>`",
                    w.rule, w.rule
                ),
            });
        }
    }

    rule_write_path(rel, &toks, &ctx, cfg, &mut findings);
    rule_unsafe(rel, &toks, &ctx, cfg, &mut findings);
    rule_no_panic(rel, &toks, &ctx, cfg, &mut findings);
    rule_hot_alloc(rel, &toks, &ctx, cfg, &mut findings);
    rule_atomics(rel, &toks, &ctx, cfg, &mut findings);

    // apply waivers: a finding is dropped when a matching-rule waiver
    // (with a reason) sits on its line or within the 3 lines above
    findings.retain(|f| {
        f.rule == "waiver"
            || !waivers.iter().any(|w| {
                w.has_reason && w.rule == f.rule && w.line <= f.line && f.line - w.line <= 3
            })
    });
    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    findings
}

/// Per-token context computed in one pass: is the token inside a
/// `#[cfg(test)]`/`#[test]` item, and which named `fn` encloses it.
struct FileContext {
    in_test: Vec<bool>,
    enclosing_fn: Vec<Option<String>>,
}

impl FileContext {
    fn build(toks: &[Token]) -> Self {
        let n = toks.len();
        let mut in_test = vec![false; n];
        let mut enclosing_fn: Vec<Option<String>> = vec![None; n];

        // pass 1: mark test items.  On `#[cfg(test)]` or `#[test]`,
        // mark every token through the end of the annotated item (the
        // matching close brace, or a `;` before any brace opens).
        let mut i = 0;
        while i < n {
            if let Some(attr_end) = test_attr_end(toks, i) {
                let mut j = attr_end;
                let mut depth = 0usize;
                let mut entered = false;
                while j < n {
                    match &toks[j].kind {
                        TokenKind::Punct('{') => {
                            depth += 1;
                            entered = true;
                        }
                        TokenKind::Punct('}') => {
                            depth = depth.saturating_sub(1);
                            if entered && depth == 0 {
                                break;
                            }
                        }
                        TokenKind::Punct(';') if !entered => break,
                        _ => {}
                    }
                    j += 1;
                }
                for k in i..=j.min(n - 1) {
                    in_test[k] = true;
                }
                i = j + 1;
            } else {
                i += 1;
            }
        }

        // pass 2: enclosing fn names.  `fn name` arms a pending frame
        // that opens at the next `{` (skipping the signature) and
        // closes at its matching `}`.  Closures don't rebind the frame;
        // nested fns nest on the stack.
        let mut stack: Vec<(usize, Option<String>)> = Vec::new(); // (depth at entry, name)
        let mut depth = 0usize;
        let mut pending: Option<String> = None;
        // paren/bracket nesting inside a signature, so the `;` in a
        // `[u8; 4]` parameter type doesn't read as "no body"
        let mut sig_depth = 0usize;
        for (idx, t) in toks.iter().enumerate() {
            enclosing_fn[idx] = stack.last().and_then(|(_, name)| name.clone());
            match &t.kind {
                TokenKind::Ident(kw) if kw == "fn" => {
                    if let Some(TokenKind::Ident(name)) = toks.get(idx + 1).map(|t| &t.kind) {
                        pending = Some(name.clone());
                        sig_depth = 0;
                        enclosing_fn[idx] = Some(name.clone());
                    }
                }
                TokenKind::Punct('(') | TokenKind::Punct('[') if pending.is_some() => {
                    sig_depth += 1;
                }
                TokenKind::Punct(')') | TokenKind::Punct(']') if pending.is_some() => {
                    sig_depth = sig_depth.saturating_sub(1);
                }
                TokenKind::Punct('{') => {
                    depth += 1;
                    if let Some(name) = pending.take() {
                        stack.push((depth, Some(name.clone())));
                        enclosing_fn[idx] = Some(name);
                    }
                }
                TokenKind::Punct('}') => {
                    if let Some((d, _)) = stack.last() {
                        if *d == depth {
                            stack.pop();
                        }
                    }
                    depth = depth.saturating_sub(1);
                }
                TokenKind::Punct(';') if sig_depth == 0 => {
                    // trait method / extern decl with no body
                    pending = None;
                }
                _ => {}
            }
        }
        FileContext { in_test, enclosing_fn }
    }
}

/// If `toks[i]` starts `#[cfg(test)]` or `#[test]`, return the index
/// one past the closing `]`.
fn test_attr_end(toks: &[Token], i: usize) -> Option<usize> {
    if !toks.get(i)?.kind.is_punct('#') || !toks.get(i + 1)?.kind.is_punct('[') {
        return None;
    }
    match &toks.get(i + 2)?.kind {
        TokenKind::Ident(a) if a == "test" && toks.get(i + 3)?.kind.is_punct(']') => Some(i + 4),
        TokenKind::Ident(a) if a == "cfg" => {
            // #[cfg(test)] exactly — #[cfg(feature = ...)] etc. pass
            if toks.get(i + 3)?.kind.is_punct('(')
                && toks.get(i + 4)?.kind.is_ident("test")
                && toks.get(i + 5)?.kind.is_punct(')')
                && toks.get(i + 6)?.kind.is_punct(']')
            {
                Some(i + 7)
            } else {
                None
            }
        }
        _ => None,
    }
}

struct Waiver {
    line: usize,
    rule: String,
    has_reason: bool,
}

fn collect_waivers(toks: &[Token]) -> Vec<Waiver> {
    let mut out = Vec::new();
    for t in toks {
        if let TokenKind::Comment(text) = &t.kind {
            if let Some(rest) = text.split("ddc-lint: allow(").nth(1) {
                if let Some((rule, tail)) = rest.split_once(')') {
                    let reason = tail
                        .trim_start_matches(|c: char| c == ' ' || c == '—' || c == '-' || c == ':');
                    out.push(Waiver {
                        line: t.line,
                        rule: rule.trim().to_string(),
                        has_reason: !reason.trim().is_empty(),
                    });
                }
            }
        }
    }
    out
}

/// R1: cell/plane mutators only on the arch write path.
fn rule_write_path(
    rel: &str,
    toks: &[Token],
    ctx: &FileContext,
    cfg: &Config,
    findings: &mut Vec<Finding>,
) {
    if cfg.write_path_allow.iter().any(|f| f == rel) {
        return;
    }
    for (i, t) in toks.iter().enumerate() {
        if ctx.in_test[i] {
            continue;
        }
        let TokenKind::Ident(name) = &t.kind else { continue };
        let is_call = toks.get(i + 1).is_some_and(|n| n.kind.is_punct('('));
        if !is_call {
            continue;
        }
        if WRITE_PATH_MUTATORS.contains(&name.as_str()) {
            findings.push(Finding {
                file: rel.to_string(),
                line: t.line,
                rule: "write_path",
                message: format!(
                    "`{name}` mutates cell state; only the arch write path \
                     ({}) may call it — route through `PimCore::write_weight`",
                    cfg.write_path_allow.join(", ")
                ),
            });
        }
        // `<planes-ish receiver>.record(...)` — WeightPlanes::record
        // bypasses the coherence + ledger bookkeeping.  The receiver
        // heuristic keeps `LatencyHistogram::record` et al. clean.
        if name == "record"
            && i >= 2
            && toks[i - 1].kind.is_punct('.')
            && matches!(&toks[i - 2].kind, TokenKind::Ident(r) if r.ends_with("planes"))
        {
            findings.push(Finding {
                file: rel.to_string(),
                line: t.line,
                rule: "write_path",
                message: "`planes.record` bypasses the single write path; \
                          route through `PimCore::write_weight`"
                    .to_string(),
            });
        }
    }
}

/// R2: `unsafe` hygiene — allowlisted modules only, each site
/// documented by a `SAFETY:` comment in the contiguous comment block
/// directly above it (or on the same line).
fn rule_unsafe(
    rel: &str,
    toks: &[Token],
    ctx: &FileContext,
    cfg: &Config,
    findings: &mut Vec<Finding>,
) {
    let allowed_here = cfg.unsafe_allow.iter().any(|f| f == rel);
    // every comment line, marked for SAFETY: an `unsafe` is documented
    // when the contiguous comment block ending on the line above it
    // (or a same-line comment) mentions SAFETY anywhere in the block
    let comment_lines: Vec<(usize, bool)> = toks
        .iter()
        .filter_map(|t| match &t.kind {
            TokenKind::Comment(c) => Some((t.line, c.contains("SAFETY"))),
            _ => None,
        })
        .collect();
    let documented = |line: usize| -> bool {
        if comment_lines.iter().any(|&(l, s)| s && l == line) {
            return true;
        }
        // last comment above the site; rustfmt may wrap the statement,
        // so the block may end up to 2 lines above the `unsafe` token
        let Some(&(end, _)) = comment_lines.iter().rev().find(|&&(l, _)| l < line) else {
            return false;
        };
        if line - end > 2 {
            return false;
        }
        let mut expect = end;
        for &(l, safety) in comment_lines.iter().rev() {
            if l > expect {
                continue;
            }
            if l == expect && l > 0 {
                if safety {
                    return true;
                }
                expect = l - 1;
            } else {
                break;
            }
        }
        false
    };
    for (i, t) in toks.iter().enumerate() {
        if ctx.in_test[i] || !t.kind.is_ident("unsafe") {
            continue;
        }
        // `unsafe fn(` — a function *pointer type* has no body to
        // document; the SAFETY burden sits on its callers
        if toks.get(i + 1).is_some_and(|n| n.kind.is_ident("fn"))
            && toks.get(i + 2).is_some_and(|n| n.kind.is_punct('('))
        {
            continue;
        }
        if !allowed_here {
            findings.push(Finding {
                file: rel.to_string(),
                line: t.line,
                rule: "unsafe_module",
                message: format!(
                    "`unsafe` outside the allowlisted modules ({})",
                    cfg.unsafe_allow.join(", ")
                ),
            });
        }
        if !documented(t.line) {
            findings.push(Finding {
                file: rel.to_string(),
                line: t.line,
                rule: "unsafe_safety",
                message: "`unsafe` without a `// SAFETY:` comment naming the \
                          disjointness or lifetime argument"
                    .to_string(),
            });
        }
    }
}

/// Does `scope` (a manifest file entry) cover function `fname`?
fn in_scope(entries: &[String], fname: Option<&str>) -> bool {
    entries.iter().any(|e| e == "*")
        || fname.is_some_and(|f| entries.iter().any(|e| e == f))
}

/// R3: no-panic serving paths.
fn rule_no_panic(
    rel: &str,
    toks: &[Token],
    ctx: &FileContext,
    cfg: &Config,
    findings: &mut Vec<Finding>,
) {
    let Some(entries) = cfg.no_panic.get(rel) else { return };
    for (i, t) in toks.iter().enumerate() {
        if ctx.in_test[i] || !in_scope(entries, ctx.enclosing_fn[i].as_deref()) {
            continue;
        }
        match &t.kind {
            TokenKind::Ident(name) if name == "unwrap" || name == "expect" => {
                let is_method = i >= 1
                    && toks[i - 1].kind.is_punct('.')
                    && toks.get(i + 1).is_some_and(|n| n.kind.is_punct('('));
                if is_method {
                    findings.push(Finding {
                        file: rel.to_string(),
                        line: t.line,
                        rule: "no_panic",
                        message: format!(
                            "`.{name}()` can abort the serving path; propagate a typed error"
                        ),
                    });
                }
            }
            TokenKind::Ident(name) if PANIC_MACROS.contains(&name.as_str()) => {
                if toks.get(i + 1).is_some_and(|n| n.kind.is_punct('!')) {
                    findings.push(Finding {
                        file: rel.to_string(),
                        line: t.line,
                        rule: "no_panic",
                        message: format!("`{name}!` aborts the serving path"),
                    });
                }
            }
            TokenKind::Punct('[') => {
                // literal index `expr[3]`: previous token ends an
                // expression, bracket holds exactly one integer
                let prev_is_expr = i >= 1
                    && matches!(
                        &toks[i - 1].kind,
                        TokenKind::Ident(_) | TokenKind::Punct(')') | TokenKind::Punct(']')
                    );
                let lit = match (toks.get(i + 1), toks.get(i + 2)) {
                    (Some(n), Some(c)) if c.kind.is_punct(']') => match &n.kind {
                        TokenKind::Number(v) if !v.contains('.') => Some(v.clone()),
                        _ => None,
                    },
                    _ => None,
                };
                if prev_is_expr {
                    if let Some(v) = lit {
                        findings.push(Finding {
                            file: rel.to_string(),
                            line: t.line,
                            rule: "no_panic",
                            message: format!(
                                "literal index `[{v}]` can panic; use `.get({v})` or a \
                                 destructuring match"
                            ),
                        });
                    }
                }
            }
            _ => {}
        }
    }
}

/// R4: allocation-free hot paths.
fn rule_hot_alloc(
    rel: &str,
    toks: &[Token],
    ctx: &FileContext,
    cfg: &Config,
    findings: &mut Vec<Finding>,
) {
    let Some(entries) = cfg.no_alloc.get(rel) else { return };
    let mut flag = |line: usize, what: &str, findings: &mut Vec<Finding>| {
        findings.push(Finding {
            file: rel.to_string(),
            line,
            rule: "hot_alloc",
            message: format!(
                "`{what}` allocates inside a hot function named in lint-hotpaths.toml \
                 (steady-state must be zero-alloc)"
            ),
        });
    };
    for (i, t) in toks.iter().enumerate() {
        if ctx.in_test[i] || !in_scope(entries, ctx.enclosing_fn[i].as_deref()) {
            continue;
        }
        let TokenKind::Ident(name) = &t.kind else { continue };
        let next_is = |c: char| toks.get(i + 1).is_some_and(|n| n.kind.is_punct(c));
        match name.as_str() {
            "Vec" if next_is(':')
                && toks.get(i + 2).is_some_and(|n| n.kind.is_punct(':'))
                && toks.get(i + 3).is_some_and(|n| n.kind.is_ident("new")) =>
            {
                flag(t.line, "Vec::new", findings);
            }
            "vec" if next_is('!') => flag(t.line, "vec!", findings),
            "format" if next_is('!') => flag(t.line, "format!", findings),
            m if HOT_ALLOC_METHODS.contains(&m)
                && i >= 1
                && toks[i - 1].kind.is_punct('.')
                // plain call or turbofish `collect::<...>`
                && (next_is('(') || (m == "collect" && next_is(':'))) =>
            {
                flag(t.line, &format!(".{m}()"), findings);
            }
            _ => {}
        }
    }
}

/// R5: every `Ordering::X` in an audited file must appear in the
/// protocol table entry for its enclosing function.
fn rule_atomics(
    rel: &str,
    toks: &[Token],
    ctx: &FileContext,
    cfg: &Config,
    findings: &mut Vec<Finding>,
) {
    if !cfg.atomics_files.iter().any(|f| f == rel) {
        return;
    }
    for (i, t) in toks.iter().enumerate() {
        if ctx.in_test[i] || !t.kind.is_ident("Ordering") {
            continue;
        }
        // `Ordering :: Variant` — a bare `Ordering` in a use statement
        // or type position doesn't name a variant and isn't audited
        let variant = match (toks.get(i + 1), toks.get(i + 2), toks.get(i + 3)) {
            (Some(a), Some(b), Some(c)) if a.kind.is_punct(':') && b.kind.is_punct(':') => {
                match &c.kind {
                    TokenKind::Ident(v) => Some(v.clone()),
                    _ => None,
                }
            }
            _ => None,
        };
        let Some(variant) = variant else { continue };
        let fname = ctx.enclosing_fn[i].clone().unwrap_or_else(|| "<module>".into());
        let key = format!("{rel}::{fname}");
        match cfg.atomics.get(&key) {
            None => findings.push(Finding {
                file: rel.to_string(),
                line: t.line,
                rule: "atomics",
                message: format!(
                    "`Ordering::{variant}` in `{fname}` has no protocol entry \
                     (`\"{key}\"`) in lint-hotpaths.toml [atomics]"
                ),
            }),
            Some(allowed) if !allowed.iter().any(|a| a == &variant) => {
                findings.push(Finding {
                    file: rel.to_string(),
                    line: t.line,
                    rule: "atomics",
                    message: format!(
                        "`Ordering::{variant}` in `{fname}` not in its documented \
                         protocol ({})",
                        allowed.join(", ")
                    ),
                })
            }
            Some(_) => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::lint::Config;
    use std::collections::BTreeMap;

    fn base_cfg() -> Config {
        Config {
            write_path_allow: vec!["arch/sram.rs".into(), "arch/pim_core.rs".into()],
            unsafe_allow: vec!["util/pool.rs".into()],
            no_alloc: BTreeMap::new(),
            no_panic: BTreeMap::new(),
            atomics: BTreeMap::new(),
            atomics_files: vec!["util/pool.rs".into()],
        }
    }

    fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn write_path_flags_stray_mutators_and_allows_the_arch() {
        let src = "fn sneak(core: &mut PimCore) { core.compartments[c].write_weight8(r, s, w); }";
        let f = lint_source("mapping/exec2.rs", src, &base_cfg());
        assert_eq!(rules_of(&f), vec!["write_path"]);
        // same text inside the allowlisted file: clean
        assert!(lint_source("arch/pim_core.rs", src, &base_cfg()).is_empty());
        // planes receiver heuristic
        let src2 = "fn sneak(&mut self) { self.planes.record(row, slot, w); }";
        assert_eq!(rules_of(&lint_source("x.rs", src2, &base_cfg())), vec!["write_path"]);
        // histogram .record is NOT a plane write
        let src3 = "fn ok(&mut self) { self.latency_hist.record(ms); }";
        assert!(lint_source("x.rs", src3, &base_cfg()).is_empty());
    }

    #[test]
    fn unsafe_rules_fire_separately() {
        let documented = "// SAFETY: lanes are disjoint\nunsafe { ptr.write(1) }";
        let undocumented = "fn f() { unsafe { ptr.write(1) } }";
        // allowlisted + documented: clean
        assert!(lint_source("util/pool.rs", documented, &base_cfg()).is_empty());
        // allowlisted + undocumented: safety only
        assert_eq!(
            rules_of(&lint_source("util/pool.rs", undocumented, &base_cfg())),
            vec!["unsafe_safety"]
        );
        // non-allowlisted + documented: module only
        assert_eq!(
            rules_of(&lint_source("model/zoo.rs", documented, &base_cfg())),
            vec!["unsafe_module"]
        );
        // fn-pointer type needs no SAFETY
        let fnptr = "struct J { call: unsafe fn(*const (), usize) }";
        assert!(lint_source("util/pool.rs", fnptr, &base_cfg()).is_empty());
    }

    #[test]
    fn cfg_test_items_are_skipped() {
        let src = "#[cfg(test)]\nmod tests {\n fn f() { x.unwrap(); unsafe { y() } }\n}";
        let mut cfg = base_cfg();
        cfg.no_panic.insert("a.rs".into(), vec!["*".into()]);
        assert!(lint_source("a.rs", src, &cfg).is_empty());
    }

    #[test]
    fn no_panic_scoping_and_idents() {
        let mut cfg = base_cfg();
        cfg.no_panic
            .insert("svc.rs".into(), vec!["serve".into()]);
        let src = "\
fn serve(x: Option<u32>, v: &[u8]) -> u32 {
    let a = x.unwrap();
    let b = x.expect(\"msg\");
    let c = v[0];
    let d = x.unwrap_or_default();
    panic!(\"boom\");
}
fn helper(x: Option<u32>) -> u32 { x.unwrap() }
";
        let f = lint_source("svc.rs", src, &cfg);
        // unwrap + expect + v[0] + panic! — helper() out of scope,
        // unwrap_or_default not a banned ident
        assert_eq!(rules_of(&f), vec!["no_panic"; 4]);
        assert!(f.iter().any(|x| x.message.contains("literal index")));
    }

    #[test]
    fn literal_index_ignores_array_types_and_ranges() {
        let mut cfg = base_cfg();
        cfg.no_panic.insert("svc.rs".into(), vec!["*".into()]);
        let src = "\
fn f(v: &[u8]) -> ([f32; 4], u8) {
    let arr = [0f32; 4];
    let s = &v[1..];
    (arr, s.iter().sum())
}
";
        assert!(lint_source("svc.rs", src, &cfg).is_empty());
    }

    #[test]
    fn hot_alloc_scoped_to_manifest_fns() {
        let mut cfg = base_cfg();
        cfg.no_alloc
            .insert("exec.rs".into(), vec!["execute".into()]);
        let src = "\
fn execute(&self, out: &mut [i32]) {
    let v = Vec::new();
    let w = vec![0u8; 4];
    self.scratch.push(1);
    let c = self.weights.clone();
    let t = out.to_vec();
    let s: Vec<u32> = it.collect::<Vec<_>>();
    let msg = format!(\"x\");
    out.fill(0); // allowed
}
fn plan(&self) -> Vec<u8> { vec![0] }
";
        let f = lint_source("exec.rs", src, &cfg);
        assert_eq!(rules_of(&f), vec!["hot_alloc"; 7]);
    }

    #[test]
    fn atomics_audit_checks_the_protocol_table() {
        let mut cfg = base_cfg();
        cfg.atomics.insert(
            "util/pool.rs::pop".into(),
            vec!["Acquire".into(), "AcqRel".into()],
        );
        let ok = "fn pop(r: &AtomicU64) { r.load(Ordering::Acquire); }";
        assert!(lint_source("util/pool.rs", ok, &cfg).is_empty());
        let relaxed = "fn pop(r: &AtomicU64) { r.load(Ordering::Relaxed); }";
        assert_eq!(rules_of(&lint_source("util/pool.rs", relaxed, &cfg)), vec!["atomics"]);
        let unknown_fn = "fn flush(r: &AtomicU64) { r.load(Ordering::Acquire); }";
        assert_eq!(
            rules_of(&lint_source("util/pool.rs", unknown_fn, &cfg)),
            vec!["atomics"]
        );
        // bare `Ordering` in a use statement is not a variant use
        let use_stmt = "use std::sync::atomic::{AtomicU64, Ordering};";
        assert!(lint_source("util/pool.rs", use_stmt, &cfg).is_empty());
        // unaudited files are not scanned
        assert!(lint_source("model/zoo.rs", relaxed, &cfg).is_empty());
    }

    #[test]
    fn waivers_suppress_with_reason_and_flag_without() {
        let mut cfg = base_cfg();
        cfg.no_panic.insert("svc.rs".into(), vec!["*".into()]);
        let with_reason = "\
fn f() {
    // ddc-lint: allow(no_panic) — chaos hook panics by design
    panic!(\"boom\");
}
";
        assert!(lint_source("svc.rs", with_reason, &cfg).is_empty());
        let without = "\
fn f() {
    // ddc-lint: allow(no_panic)
    panic!(\"boom\");
}
";
        let f = lint_source("svc.rs", without, &cfg);
        // the waiver is flagged AND does not suppress
        assert_eq!(rules_of(&f), vec!["waiver", "no_panic"]);
        // a waiver for a different rule does not suppress
        let wrong_rule = "\
fn f() {
    // ddc-lint: allow(hot_alloc) — wrong rule
    panic!(\"boom\");
}
";
        assert_eq!(rules_of(&lint_source("svc.rs", wrong_rule, &cfg)), vec!["no_panic"]);
    }

    #[test]
    fn enclosing_fn_survives_closures_and_nesting() {
        let mut cfg = base_cfg();
        cfg.no_alloc.insert("x.rs".into(), vec!["outer".into()]);
        let src = "\
fn outer(&self) {
    let f = |x: u32| { self.buf.push(x) };
    f(1);
}
fn other(&self) { self.buf.push(2); }
";
        let f = lint_source("x.rs", src, &cfg);
        assert_eq!(rules_of(&f), vec!["hot_alloc"]);
        assert_eq!(f[0].line, 2);
    }
}
