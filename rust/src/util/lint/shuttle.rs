//! Loom-lite deterministic-interleaving checker ("shuttle") for the
//! repo's two lock-free protocols.
//!
//! The static rules can prove an `Ordering` matches the documented
//! table, but not that the *protocol* is right.  This module
//! model-checks the protocols themselves: each virtual thread is an
//! explicit state machine whose `step` performs exactly **one** atomic
//! action (load, CAS, store, or the guarded work), and a seeded
//! scheduler ([`crate::util::rng::Rng`]) picks which runnable thread
//! steps next.  Every interleaving the real hardware could produce at
//! the granularity of atomic accesses is reachable by some seed; CI
//! drives ≥1000 seeds through both models.
//!
//! Two protocols, mirrored statement-for-statement from the sources:
//!
//! - **WorkPool range-steal** (`util/pool.rs`): per-lane packed
//!   `(next<<32)|end` ranges, pop-own-front CAS vs steal-upper-half
//!   CAS, per-victim scan loads.  Invariant: every unit executes
//!   exactly once.
//! - **Admission CAS gate** (`coordinator/service.rs::try_admit`):
//!   load + bound check + `compare_exchange`, released by a
//!   `fetch_sub`.  Invariants: concurrent admissions never exceed the
//!   bound, the counter returns to zero, every attempt is admitted or
//!   rejected exactly once.
//!
//! Each model also ships a deliberately-broken variant (the CAS
//! replaced by the classic load-then-store lost update).  The test
//! suite asserts the checker *catches* those — a model checker that
//! can't find a planted bug proves nothing by passing.

use crate::util::rng::Rng;

/// Outcome of a seeded exploration.
#[derive(Debug, Clone)]
pub struct ShuttleReport {
    /// Seeds (schedules) explored.
    pub schedules: u64,
    /// Total atomic steps across all schedules.
    pub steps: u64,
    /// Human-readable invariant violations, each tagged with its seed.
    /// Exploration continues across seeds so the count is meaningful.
    pub violations: Vec<String>,
}

impl ShuttleReport {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Per-schedule step budget.  Both protocols are lock-free (a failed
/// CAS implies another thread's success), so hitting this means the
/// model livelocked — reported as a violation, not an infinite loop.
const STEP_BUDGET: u64 = 200_000;

// ---------------------------------------------------------------------------
// WorkPool range-steal model
// ---------------------------------------------------------------------------

fn pack(next: u32, end: u32) -> u64 {
    ((next as u64) << 32) | end as u64
}

fn unpack(v: u64) -> (u32, u32) {
    ((v >> 32) as u32, v as u32)
}

/// Program counter of one virtual lane.  Every variant's `step` is one
/// atomic access on the shared ranges (or the unit execution itself).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LanePc {
    /// `pop`: load own range.
    PopLoad,
    /// `pop`: CAS own range from the loaded value to `next+1`.
    PopCas { seen: u64 },
    /// Buggy variant: blind store of `next+1` computed from a stale
    /// load — the lost update a CAS exists to prevent.
    PopStoreRacy { seen: u64 },
    /// Execute one unit (the closure call in `run_share`).
    Exec { unit: u32 },
    /// Victim scan: load `ranges[victim]`, tracking the richest.
    ScanLoad { victim: u32, best: u32, best_rem: u32 },
    /// `steal`: fresh load of the chosen victim.
    StealLoad { victim: u32 },
    /// `steal`: CAS the victim down to its lower half.
    StealCas { victim: u32, seen: u64 },
    /// Store the stolen upper half into our own range.
    SetOwn { lo: u32, hi: u32 },
    Done,
}

struct StealModel {
    ranges: Vec<u64>,
    lanes: usize,
    /// `executed[u]` = times unit `u` ran; >1 is an immediate violation.
    executed: Vec<u32>,
}

/// Advance lane `me` by one step.  Returns an invariant violation
/// message if this step broke exactly-once execution.
fn steal_step(m: &mut StealModel, pcs: &mut [LanePc], me: usize, racy_pop: bool) -> Option<String> {
    let pc = pcs[me];
    pcs[me] = match pc {
        LanePc::PopLoad => {
            let seen = m.ranges[me];
            let (next, end) = unpack(seen);
            if next >= end {
                LanePc::ScanLoad { victim: 0, best: u32::MAX, best_rem: 0 }
            } else if racy_pop {
                LanePc::PopStoreRacy { seen }
            } else {
                LanePc::PopCas { seen }
            }
        }
        LanePc::PopCas { seen } => {
            let (next, end) = unpack(seen);
            if m.ranges[me] == seen {
                m.ranges[me] = pack(next + 1, end);
                LanePc::Exec { unit: next }
            } else {
                LanePc::PopLoad
            }
        }
        LanePc::PopStoreRacy { seen } => {
            let (next, end) = unpack(seen);
            m.ranges[me] = pack(next + 1, end);
            LanePc::Exec { unit: next }
        }
        LanePc::Exec { unit } => {
            m.executed[unit as usize] += 1;
            if m.executed[unit as usize] > 1 {
                return Some(format!("unit {unit} executed twice"));
            }
            LanePc::PopLoad
        }
        LanePc::ScanLoad { victim, best, best_rem } => {
            let mut v = victim as usize;
            if v == me {
                v += 1; // skip self without consuming an atomic step
            }
            if v >= m.lanes {
                if best_rem == 0 {
                    LanePc::Done
                } else if best_rem >= 2 {
                    LanePc::StealLoad { victim: best }
                } else {
                    // richest victim holds a single unstealable unit:
                    // its owner drains it (`yield_now` + rescan)
                    LanePc::ScanLoad { victim: 0, best: u32::MAX, best_rem: 0 }
                }
            } else {
                let (next, end) = unpack(m.ranges[v]);
                let rem = end.saturating_sub(next);
                let (best, best_rem) = if rem > best_rem { (v as u32, rem) } else { (best, best_rem) };
                LanePc::ScanLoad { victim: v as u32 + 1, best, best_rem }
            }
        }
        LanePc::StealLoad { victim } => {
            let seen = m.ranges[victim as usize];
            let (next, end) = unpack(seen);
            if end.saturating_sub(next) < 2 {
                // raced away: rescan from the top
                LanePc::ScanLoad { victim: 0, best: u32::MAX, best_rem: 0 }
            } else {
                LanePc::StealCas { victim, seen }
            }
        }
        LanePc::StealCas { victim, seen } => {
            let (next, end) = unpack(seen);
            let mid = next + (end - next) / 2;
            if m.ranges[victim as usize] == seen {
                m.ranges[victim as usize] = pack(next, mid);
                LanePc::SetOwn { lo: mid, hi: end }
            } else {
                LanePc::StealLoad { victim }
            }
        }
        LanePc::SetOwn { lo, hi } => {
            m.ranges[me] = pack(lo, hi);
            LanePc::PopLoad
        }
        LanePc::Done => LanePc::Done,
    };
    None
}

fn run_steal_schedule(seed: u64, lanes: usize, units: u32, racy_pop: bool) -> (u64, Option<String>) {
    // initial even split, same as WorkPool::run
    let mut ranges = vec![0u64; lanes];
    let per = units / lanes as u32;
    let extra = units % lanes as u32;
    let mut start = 0u32;
    for (lane, r) in ranges.iter_mut().enumerate() {
        let len = per + u32::from((lane as u32) < extra);
        *r = pack(start, start + len);
        start += len;
    }
    let mut m = StealModel { ranges, lanes, executed: vec![0; units as usize] };
    let mut pcs = vec![LanePc::PopLoad; lanes];
    let mut rng = Rng::new(seed);
    let mut steps = 0u64;
    loop {
        let runnable: Vec<usize> =
            (0..lanes).filter(|&l| pcs[l] != LanePc::Done).collect();
        if runnable.is_empty() {
            break;
        }
        if steps >= STEP_BUDGET {
            return (steps, Some("step budget exhausted (livelock?)".into()));
        }
        let me = runnable[rng.below(runnable.len() as u64) as usize];
        steps += 1;
        if let Some(v) = steal_step(&mut m, &mut pcs, me, racy_pop) {
            return (steps, Some(v));
        }
    }
    for (u, &n) in m.executed.iter().enumerate() {
        if n != 1 {
            return (steps, Some(format!("unit {u} executed {n} times (want 1)")));
        }
    }
    (steps, None)
}

/// Explore `seeds` schedules of the faithful steal protocol.
pub fn check_steal_protocol(seeds: u64, lanes: usize, units: u32) -> ShuttleReport {
    explore(seeds, |s| run_steal_schedule(s, lanes, units, false))
}

/// Same exploration over the planted-bug variant (pop is a blind
/// load-then-store).  Expected to report violations — the checker's
/// own power test.
pub fn check_steal_protocol_buggy(seeds: u64, lanes: usize, units: u32) -> ShuttleReport {
    explore(seeds, |s| run_steal_schedule(s, lanes, units, true))
}

// ---------------------------------------------------------------------------
// Admission CAS gate model
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ClientPc {
    /// `try_admit`: load `in_flight`.
    Load,
    /// `try_admit`: `compare_exchange(seen, seen + 1)`.
    Cas { seen: i64 },
    /// Buggy variant: blind `store(seen + 1)` — two clients can both
    /// claim the last slot.
    StoreRacy { seen: i64 },
    /// Holding an admitted slot (the batch executing).
    Work,
    /// `finish_request`: `fetch_sub(1)`.
    Finish,
    Done { admitted: bool },
}

struct GateModel {
    in_flight: i64,
    bound: i64,
    /// Model-level ground truth of concurrently admitted clients.
    active: i64,
}

fn gate_step(m: &mut GateModel, pcs: &mut [ClientPc], me: usize, racy: bool) -> Option<String> {
    let pc = pcs[me];
    pcs[me] = match pc {
        ClientPc::Load => {
            let seen = m.in_flight;
            if seen >= m.bound {
                ClientPc::Done { admitted: false }
            } else if racy {
                ClientPc::StoreRacy { seen }
            } else {
                ClientPc::Cas { seen }
            }
        }
        ClientPc::Cas { seen } => {
            if m.in_flight == seen {
                m.in_flight = seen + 1;
                m.active += 1;
                if m.active > m.bound {
                    return Some(format!(
                        "{} clients admitted concurrently (bound {})",
                        m.active, m.bound
                    ));
                }
                ClientPc::Work
            } else {
                ClientPc::Load
            }
        }
        ClientPc::StoreRacy { seen } => {
            m.in_flight = seen + 1;
            m.active += 1;
            if m.active > m.bound {
                return Some(format!(
                    "{} clients admitted concurrently (bound {})",
                    m.active, m.bound
                ));
            }
            ClientPc::Work
        }
        ClientPc::Work => ClientPc::Finish,
        ClientPc::Finish => {
            m.in_flight -= 1;
            m.active -= 1;
            ClientPc::Done { admitted: true }
        }
        done @ ClientPc::Done { .. } => done,
    };
    None
}

fn run_gate_schedule(seed: u64, clients: usize, bound: i64, racy: bool) -> (u64, Option<String>) {
    let mut m = GateModel { in_flight: 0, bound, active: 0 };
    let mut pcs = vec![ClientPc::Load; clients];
    let mut rng = Rng::new(seed);
    let mut steps = 0u64;
    loop {
        let runnable: Vec<usize> = (0..clients)
            .filter(|&c| !matches!(pcs[c], ClientPc::Done { .. }))
            .collect();
        if runnable.is_empty() {
            break;
        }
        if steps >= STEP_BUDGET {
            return (steps, Some("step budget exhausted (livelock?)".into()));
        }
        let me = runnable[rng.below(runnable.len() as u64) as usize];
        steps += 1;
        if let Some(v) = gate_step(&mut m, &mut pcs, me, racy) {
            return (steps, Some(v));
        }
    }
    if m.in_flight != 0 {
        return (steps, Some(format!("final in_flight = {} (want 0)", m.in_flight)));
    }
    let (mut admitted, mut rejected) = (0usize, 0usize);
    for pc in &pcs {
        match pc {
            ClientPc::Done { admitted: true } => admitted += 1,
            ClientPc::Done { admitted: false } => rejected += 1,
            _ => unreachable!("loop exits only when all clients are done"),
        }
    }
    if admitted + rejected != clients {
        return (
            steps,
            Some(format!("{admitted} admitted + {rejected} rejected != {clients} attempts")),
        );
    }
    (steps, None)
}

/// Explore `seeds` schedules of the faithful admission gate.
pub fn check_admission_gate(seeds: u64, clients: usize, bound: i64) -> ShuttleReport {
    explore(seeds, |s| run_gate_schedule(s, clients, bound, false))
}

/// The planted-bug variant (blind store instead of CAS) — expected to
/// report violations.
pub fn check_admission_gate_buggy(seeds: u64, clients: usize, bound: i64) -> ShuttleReport {
    explore(seeds, |s| run_gate_schedule(s, clients, bound, true))
}

fn explore(seeds: u64, mut run: impl FnMut(u64) -> (u64, Option<String>)) -> ShuttleReport {
    let mut report = ShuttleReport { schedules: 0, steps: 0, violations: Vec::new() };
    for seed in 0..seeds {
        let (steps, violation) = run(seed);
        report.schedules += 1;
        report.steps += steps;
        if let Some(v) = violation {
            // keep the report readable when a planted bug fires on
            // most seeds
            if report.violations.len() < 16 {
                report.violations.push(format!("seed {seed}: {v}"));
            }
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    // trimmed exploration under Miri: each interpreted step is ~1000x
    // slower, and the interleavings are identical either way
    const SEEDS: u64 = if cfg!(miri) { 32 } else { 1000 };

    #[test]
    fn steal_protocol_is_exactly_once_across_seeds() {
        let r = check_steal_protocol(SEEDS, 4, 24);
        assert_eq!(r.schedules, SEEDS);
        assert!(r.ok(), "violations: {:?}", r.violations);
        // degenerate shapes: single lane, fewer units than lanes
        assert!(check_steal_protocol(SEEDS / 4, 1, 7).ok());
        assert!(check_steal_protocol(SEEDS / 4, 6, 3).ok());
    }

    #[test]
    fn admission_gate_holds_bound_across_seeds() {
        let r = check_admission_gate(SEEDS, 6, 2);
        assert_eq!(r.schedules, SEEDS);
        assert!(r.ok(), "violations: {:?}", r.violations);
        assert!(check_admission_gate(SEEDS / 4, 3, 1).ok());
    }

    #[test]
    fn planted_pop_race_is_caught() {
        // (4 lanes, 12 units) trips the lost update by seed 13 —
        // inside even the Miri-trimmed exploration
        let r = check_steal_protocol_buggy(SEEDS, 4, 12);
        assert!(
            !r.ok(),
            "checker failed to find the planted lost-update in {SEEDS} seeds"
        );
    }

    #[test]
    fn planted_gate_race_is_caught() {
        let r = check_admission_gate_buggy(SEEDS, 6, 2);
        assert!(
            !r.ok(),
            "checker failed to find the planted blind-store race in {SEEDS} seeds"
        );
    }

    #[test]
    fn schedules_are_deterministic_per_seed() {
        let a = check_steal_protocol(50, 3, 10);
        let b = check_steal_protocol(50, 3, 10);
        assert_eq!(a.steps, b.steps);
    }
}
