//! Small self-contained substrates: JSON, deterministic PRNG, a mini
//! property-testing harness and ASCII table rendering.
//!
//! These exist because the build is fully offline (vendored crates only):
//! no serde/proptest/prettytable — so the substrates are part of the
//! library, per the reproduction ground rules.

pub mod benchkit;
pub mod env;
pub mod json;
pub mod lint;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod table;
