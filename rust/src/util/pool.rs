//! Hand-rolled work-stealing thread pool for the host-parallel
//! executors (ROADMAP "Parallelize pixel blocks") — offline deps only,
//! so no rayon/crossbeam: everything here is std.
//!
//! # Shape
//!
//! A [`WorkPool`] of width `N` owns `N - 1` parked worker threads; the
//! caller of [`WorkPool::run`] is always lane 0, so width 1 spawns no
//! threads and runs the units inline — the serial path *is* the
//! degenerate pool.
//!
//! [`WorkPool::run`] executes `f(lane, unit)` for every `unit in
//! 0..units` exactly once, with a **scoped** borrow: `f` may capture
//! non-`'static` references (the resident `PlannedConv`, the im2col
//! staging, the output slice) because `run` does not return until every
//! worker has finished the job — the closure outlives all uses by
//! construction, no `Arc`/`'static` gymnastics required.
//!
//! # Work distribution
//!
//! Each lane owns a half-open index range packed into one `AtomicU64`
//! (`next` in the high half, `end` in the low half).  Lanes pop from
//! the front of their own range; a lane whose range is empty steals the
//! *upper half* of the richest victim's range with a single CAS
//! (chase-lev in spirit, but over index ranges instead of deques — the
//! work units are dense integers, so no buffer is needed at all).  A
//! range holding one last unit is never stolen: its owner is by
//! construction still draining it, and leaving the tail avoids the
//! two-thieves-one-unit CAS storm.
//!
//! # Allocation discipline
//!
//! The dispatch path allocates nothing: job hand-off is a data pointer
//! plus a monomorphized trampoline stored in a pre-existing slot,
//! ranges are pre-sized atomics, and wake-up is a futex-backed
//! `Condvar`.  This is what keeps the steady-state zero-alloc contract
//! of `Session::infer_batch_into` intact at pool widths > 1
//! (`tests/alloc_steady_state.rs`).

use std::any::Any;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Hard ceiling on pool width (range indices are packed into u32
/// halves and lane scans are linear; 64 lanes is far beyond any
/// geometry this repo simulates).
pub const MAX_THREADS: usize = 64;

/// Resolve a requested pool width.  Precedence (same contract as
/// `DDC_GRID` / `DDC_WORKERS`): an explicit `requested >= 1` wins,
/// `0` means "unset" and falls back to the `DDC_THREADS` environment
/// variable, then to 1 (the serial path).  An unparseable
/// `DDC_THREADS` is *warned about* on stderr and treated as unset —
/// never silently ignored.  The result is clamped to
/// `1..=`[`MAX_THREADS`].
pub fn resolve_threads(requested: usize) -> usize {
    let n = if requested > 0 {
        requested
    } else {
        crate::util::env::resolve_env_knob("DDC_THREADS", 1, "1", crate::util::env::parse_positive)
    };
    n.clamp(1, MAX_THREADS)
}

/// A raw `*mut T` asserting that cross-thread access is externally
/// synchronized: every worker touches a disjoint set of indices (its
/// own lane slot, or the disjoint output region of its work unit).
/// The pool's barrier (`run` returns only after all lanes finish)
/// sequences those writes before the caller reads them.
pub struct SharedMut<T>(pub *mut T);

// manual impls: a derive would demand `T: Copy`, but copying the
// *pointer* is always fine
impl<T> Clone for SharedMut<T> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<T> Copy for SharedMut<T> {}

// SAFETY: `SharedMut` is only constructed over allocations that outlive
// the pool job (the caller blocks in `run` until every lane returns),
// and every lane dereferences a disjoint index set — disjointness is
// the caller's stated contract (see the struct docs), so no two
// threads ever alias the same element.
unsafe impl<T: Send> Send for SharedMut<T> {}
// SAFETY: same argument as `Send` — shared access is index-disjoint and
// the barrier in `run` sequences all writes before any caller read.
unsafe impl<T: Send> Sync for SharedMut<T> {}

/// Type-erased job: closure data pointer + monomorphized trampoline.
#[derive(Clone, Copy)]
struct Job {
    data: *const (),
    call: unsafe fn(*const (), usize, usize),
}

// SAFETY: the pointer is only dereferenced while `run` blocks the
// owning thread, so the closure it points at is alive and `Sync`.
unsafe impl Send for Job {}

// SAFETY contract: `data` must point at a live `F`; upheld because the
// only caller chain is `run` → worker loop, and `run` blocks until all
// lanes drain the job, keeping the stack-borrowed closure alive.
unsafe fn trampoline<F: Fn(usize, usize) + Sync>(data: *const (), lane: usize, unit: usize) {
    (*(data as *const F))(lane, unit)
}

struct State {
    /// Bumped once per job; workers use it to tell jobs apart.
    epoch: u64,
    job: Option<Job>,
    /// Worker lanes still inside the current job.
    active: usize,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    work_cv: Condvar,
    done_cv: Condvar,
    /// Per-lane index range, packed `(next << 32) | end`.
    ranges: Vec<AtomicU64>,
    /// Set when any lane's closure panicked during the current job;
    /// `run` converts it into a caller-side panic after the barrier.
    panicked: AtomicBool,
}

#[inline]
fn pack(next: u32, end: u32) -> u64 {
    ((next as u64) << 32) | end as u64
}

#[inline]
fn unpack(v: u64) -> (u32, u32) {
    ((v >> 32) as u32, v as u32)
}

/// Pop the front unit of a lane's own range.
fn pop(range: &AtomicU64) -> Option<usize> {
    let mut cur = range.load(Ordering::Acquire);
    loop {
        let (next, end) = unpack(cur);
        if next >= end {
            return None;
        }
        match range.compare_exchange_weak(
            cur,
            pack(next + 1, end),
            Ordering::AcqRel,
            Ordering::Acquire,
        ) {
            Ok(_) => return Some(next as usize),
            Err(seen) => cur = seen,
        }
    }
}

/// Steal the upper half of a victim's range (leaving the lower half,
/// which the victim pops from).  Returns the stolen `(start, end)`.
/// A single remaining unit is left to its owner — see the module docs.
fn steal(victim: &AtomicU64) -> Option<(u32, u32)> {
    let mut cur = victim.load(Ordering::Acquire);
    loop {
        let (next, end) = unpack(cur);
        if end.saturating_sub(next) < 2 {
            return None;
        }
        let mid = next + (end - next) / 2;
        match victim.compare_exchange_weak(
            cur,
            pack(next, mid),
            Ordering::AcqRel,
            Ordering::Acquire,
        ) {
            Ok(_) => return Some((mid, end)),
            Err(seen) => cur = seen,
        }
    }
}

/// One lane's share of a job: drain own range, then steal halves from
/// the richest victim until every range is empty.
fn run_share(shared: &Shared, lane: usize, job: Job) {
    loop {
        while let Some(unit) = pop(&shared.ranges[lane]) {
            // SAFETY: `run` keeps the closure alive until all lanes
            // finish; `Job` is only ever built from a `Sync` closure.
            unsafe { (job.call)(job.data, lane, unit) };
        }
        // own range empty: pick the victim with the most work left
        let mut victim = lane;
        let mut victim_remaining = 0u32;
        for (v, range) in shared.ranges.iter().enumerate() {
            if v == lane {
                continue;
            }
            let (next, end) = unpack(range.load(Ordering::Acquire));
            let remaining = end.saturating_sub(next);
            if remaining > victim_remaining {
                victim_remaining = remaining;
                victim = v;
            }
        }
        if victim_remaining == 0 {
            // every range was empty at scan time; ranges only drain, so
            // (modulo in-flight steals, which move work to live lanes)
            // the job is done for this lane
            return;
        }
        match steal(&shared.ranges[victim]) {
            Some((s, e)) => shared.ranges[lane].store(pack(s, e), Ordering::Release),
            // nothing stealable (single-unit tails, or we lost the
            // race): let the owners run instead of burning the core on
            // a tight rescan loop while the tail drains
            None => std::thread::yield_now(),
        }
        // rescan from the top
    }
}

/// [`run_share`] behind a panic guard.  A panicking closure must never
/// unwind past the job barrier (other lanes still hold the raw job
/// pointer), and a dead lane must not strand its remaining units — a
/// single-unit range is unstealable by design, so the survivors would
/// otherwise spin on it forever.  On panic: abandon this lane's range,
/// raise the shared flag, and hand the payload back to the caller.
fn run_share_guarded(shared: &Shared, lane: usize, job: Job) -> Option<Box<dyn Any + Send>> {
    match panic::catch_unwind(AssertUnwindSafe(|| run_share(shared, lane, job))) {
        Ok(()) => None,
        Err(payload) => {
            shared.ranges[lane].store(0, Ordering::Release);
            shared.panicked.store(true, Ordering::Release);
            Some(payload)
        }
    }
}

fn worker_loop(shared: Arc<Shared>, lane: usize) {
    let mut seen_epoch = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen_epoch {
                    if let Some(job) = st.job {
                        seen_epoch = st.epoch;
                        break job;
                    }
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        // worker panics are flagged (run() re-raises them on the
        // caller) — this lane must still decrement `active`, or the
        // barrier would never open
        let _ = run_share_guarded(&shared, lane, job);
        let mut st = shared.state.lock().unwrap();
        st.active -= 1;
        if st.active == 0 {
            shared.done_cv.notify_all();
        }
    }
}

/// The pool.  See the module docs for the execution model.
pub struct WorkPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    width: usize,
}

impl WorkPool {
    /// Build a pool of `threads` total lanes (caller included), so
    /// `threads - 1` worker threads are spawned.  `threads` is clamped
    /// to `1..=`[`MAX_THREADS`].
    pub fn new(threads: usize) -> WorkPool {
        let width = threads.clamp(1, MAX_THREADS);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                active: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            ranges: (0..width).map(|_| AtomicU64::new(0)).collect(),
            panicked: AtomicBool::new(false),
        });
        let handles = (1..width)
            .map(|lane| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("ddc-pool-{lane}"))
                    .spawn(move || worker_loop(shared, lane))
                    .expect("spawning pool worker")
            })
            .collect();
        WorkPool {
            shared,
            handles,
            width,
        }
    }

    /// Total lanes, caller included.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Execute `f(lane, unit)` for every `unit in 0..units` exactly
    /// once across the pool's lanes, blocking until all units are done.
    /// `lane < width()` identifies the executing lane, so callers can
    /// hand each lane its own scratch state; which lane runs which unit
    /// is *not* deterministic — callers must make units independent
    /// (disjoint output regions), which also makes results identical at
    /// every pool width.
    ///
    /// Takes `&mut self`: a pool runs one job at a time.  The
    /// steady-state path performs no heap allocation.
    ///
    /// # Panics
    ///
    /// If `f` panics on any lane the panic is re-raised here — but
    /// only *after* every lane has left the job, so no lane ever
    /// touches a dead closure or a freed output buffer.  The pool
    /// itself stays usable afterwards.
    pub fn run<F: Fn(usize, usize) + Sync>(&mut self, units: usize, f: &F) {
        if units == 0 {
            return;
        }
        if self.width == 1 {
            for unit in 0..units {
                f(0, unit);
            }
            return;
        }
        assert!(units <= u32::MAX as usize, "unit count overflows the packed ranges");
        // carve the initial even split (remainder to the low lanes)
        let per = units / self.width;
        let extra = units % self.width;
        let mut start = 0usize;
        for (lane, range) in self.shared.ranges.iter().enumerate() {
            let len = per + usize::from(lane < extra);
            range.store(pack(start as u32, (start + len) as u32), Ordering::Release);
            start += len;
        }
        let job = Job {
            data: f as *const F as *const (),
            call: trampoline::<F>,
        };
        {
            let mut st = self.shared.state.lock().unwrap();
            st.epoch += 1;
            st.job = Some(job);
            st.active = self.width - 1;
            self.shared.work_cv.notify_all();
        }
        // the caller is lane 0, panic-guarded like every other lane:
        // we must reach the barrier below before unwinding, because
        // the workers still hold the raw job pointer until it opens
        let caller_panic = run_share_guarded(&self.shared, 0, job);
        let mut st = self.shared.state.lock().unwrap();
        while st.active != 0 {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        st.job = None;
        drop(st);
        // swap unconditionally: a caller-lane panic also raised the
        // flag, and it must not leak into the next job
        let lane_panicked = self.shared.panicked.swap(false, Ordering::AcqRel);
        if let Some(payload) = caller_panic {
            panic::resume_unwind(payload);
        }
        if lane_panicked {
            panic!("a pool worker lane panicked while executing the job");
        }
    }
}

impl Drop for WorkPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    /// Miri interprets every step ~1000x slower; the schedules a small
    /// run explores are the same shape, so trim counts, not coverage.
    const fn trim(full: usize, miri: usize) -> usize {
        if cfg!(miri) {
            miri
        } else {
            full
        }
    }

    #[test]
    fn every_unit_runs_exactly_once() {
        for width in [1usize, 2, 3, 8] {
            let mut pool = WorkPool::new(width);
            let units = trim(257, 33); // odd + > width so the split is uneven
            let hits: Vec<AtomicUsize> = (0..units).map(|_| AtomicUsize::new(0)).collect();
            pool.run(units, &|_, u| {
                hits[u].fetch_add(1, Ordering::Relaxed);
            });
            for (u, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "unit {u} at width {width}");
            }
        }
    }

    #[test]
    fn lanes_are_in_range_and_caller_is_lane_zero() {
        let mut pool = WorkPool::new(4);
        let width = pool.width();
        let bad = AtomicUsize::new(0);
        pool.run(100, &|lane, _| {
            if lane >= width {
                bad.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(bad.load(Ordering::Relaxed), 0);
        // width 1 runs inline on the caller: lane must always be 0
        let mut serial = WorkPool::new(1);
        serial.run(10, &|lane, _| assert_eq!(lane, 0));
    }

    #[test]
    fn pool_is_reusable_across_jobs_of_different_sizes() {
        let mut pool = WorkPool::new(3);
        for units in [1usize, 5, 64, 2, 0, 129] {
            let count = AtomicUsize::new(0);
            pool.run(units, &|_, _| {
                count.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(count.load(Ordering::Relaxed), units);
        }
    }

    #[test]
    fn uneven_unit_costs_still_cover_everything() {
        // front-loaded cost: lane 0's initial range is far more
        // expensive, so the other lanes must steal to finish
        let mut pool = WorkPool::new(4);
        let units = trim(64, 16);
        let hits: Vec<AtomicUsize> = (0..units).map(|_| AtomicUsize::new(0)).collect();
        pool.run(units, &|_, u| {
            let spins: u64 = if u < 8 { trim(20_000, 200) as u64 } else { 10 };
            let mut acc = 0u64;
            for i in 0..spins {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            std::hint::black_box(acc);
            hits[u].fetch_add(1, Ordering::Relaxed);
        });
        for (u, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "unit {u}");
        }
    }

    #[test]
    fn disjoint_writes_through_shared_mut() {
        let mut pool = WorkPool::new(4);
        let mut out = vec![0u64; trim(1000, 64)];
        let base = SharedMut(out.as_mut_ptr());
        pool.run(out.len(), &|_, u| {
            // SAFETY: unit indices are unique, so writes are disjoint
            unsafe { *base.0.add(u) = u as u64 * 3 };
        });
        for (u, &v) in out.iter().enumerate() {
            assert_eq!(v, u as u64 * 3);
        }
    }

    #[test]
    fn panicking_job_neither_hangs_nor_poisons_the_pool() {
        // whichever lane hits the panicking unit, run() must re-raise
        // after the barrier (no deadlock on a dead worker, no unwind
        // past live raw job pointers) and the pool must stay usable
        let mut pool = WorkPool::new(4);
        for _ in 0..2 {
            let result = panic::catch_unwind(AssertUnwindSafe(|| {
                pool.run(trim(64, 16), &|_, u| {
                    if u == 13 {
                        panic!("boom");
                    }
                });
            }));
            assert!(result.is_err(), "panic in a job unit must propagate");
            // the same pool still runs clean jobs to completion
            let count = AtomicUsize::new(0);
            let n = trim(100, 20);
            pool.run(n, &|_, _| {
                count.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(count.load(Ordering::Relaxed), n, "pool poisoned after panic");
        }
    }

    #[test]
    fn range_pack_roundtrip_and_steal_split() {
        assert_eq!(unpack(pack(7, 19)), (7, 19));
        let r = AtomicU64::new(pack(0, 10));
        let (s, e) = steal(&r).expect("steal half");
        assert_eq!((s, e), (5, 10));
        assert_eq!(unpack(r.load(Ordering::Relaxed)), (0, 5));
        // a single remaining unit is left to its owner
        let one = AtomicU64::new(pack(4, 5));
        assert!(steal(&one).is_none());
        assert_eq!(pop(&one), Some(4));
        assert_eq!(pop(&one), None);
    }

    #[test]
    fn resolve_threads_explicit_and_clamped() {
        assert_eq!(resolve_threads(1), 1);
        assert_eq!(resolve_threads(7), 7);
        assert_eq!(resolve_threads(10_000), MAX_THREADS);
        // the env fallback parser (resolve_threads(0) itself would read
        // the live environment — racy under the parallel test harness)
        assert_eq!(crate::util::env::parse_positive("4"), Ok(4));
        assert_eq!(crate::util::env::parse_positive(" 2 "), Ok(2));
        assert!(crate::util::env::parse_positive("0").is_err());
        assert!(crate::util::env::parse_positive("lots").is_err());
    }
}
