//! Mini property-testing harness (offline substrate for proptest).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` on `cases` random inputs
//! drawn by `gen` from a deterministic [`Rng`]; on failure it reports the
//! case index and the debug form of the failing input so the exact case
//! can be replayed from the seed.

use super::rng::Rng;
use std::fmt::Debug;

/// Run `prop` against `cases` generated inputs; panic with a replayable
/// report on the first failure.
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property failed at case {case}/{cases} (seed {seed}):\n  input = {input:?}"
            );
        }
    }
}

/// Like [`forall`] but the property returns `Result<(), String>` so the
/// failure message can carry diagnostic detail.
pub fn forall_explain<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case}/{cases} (seed {seed}): {msg}\n  input = {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(1, 100, |r| r.range_i64(0, 10), |x| *x < 10);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure() {
        forall(2, 100, |r| r.range_i64(0, 10), |x| *x < 9);
    }

    #[test]
    fn explain_variant() {
        forall_explain(
            3,
            50,
            |r| (r.int8(), r.int8()),
            |(a, b)| {
                let s = (*a as i32) + (*b as i32);
                if s.abs() <= 256 {
                    Ok(())
                } else {
                    Err(format!("sum {s} out of range"))
                }
            },
        );
    }
}
