//! Deterministic xorshift64* PRNG (offline substrate for `rand`).
//!
//! Used by tests, the property harness, workload generators and the
//! synthetic-input paths.  Deterministic by construction — simulator runs
//! are exactly reproducible from the seed recorded in EXPERIMENTS.md.

/// xorshift64* — tiny, fast, good enough statistical quality for
/// workload generation (not cryptographic).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // avoid the all-zero fixed point
        Rng {
            state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15),
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // modulo bias is negligible for our n << 2^64
        self.next_u64() % n
    }

    /// Uniform in `[lo, hi)` (i64).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + (self.below((hi - lo) as u64) as i64)
    }

    /// Uniform INT8 value in `[-128, 127]`.
    pub fn int8(&mut self) -> i8 {
        self.range_i64(-128, 128) as i8
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of INT8 values.
    pub fn int8_vec(&mut self, n: usize) -> Vec<i8> {
        (0..n).map(|_| self.int8()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn int8_covers_extremes() {
        let mut r = Rng::new(11);
        let vals: Vec<i8> = (0..20_000).map(|_| r.int8()).collect();
        assert!(vals.contains(&-128));
        assert!(vals.contains(&127));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(5);
        let mean: f64 = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
