//! ASCII table rendering for the report generators (the paper's tables
//! and figure data series are printed as aligned text tables).

/// A simple column-aligned table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(title: impl Into<String>) -> Self {
        Table {
            title: Some(title.into()),
            ..Default::default()
        }
    }

    pub fn header(mut self, cols: &[&str]) -> Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cols: Vec<String>) -> &mut Self {
        self.rows.push(cols);
        self
    }

    pub fn render(&self) -> String {
        let ncols = self
            .rows
            .iter()
            .map(Vec::len)
            .chain(std::iter::once(self.header.len()))
            .max()
            .unwrap_or(0);
        let mut widths = vec![0usize; ncols];
        let measure = |row: &[String], widths: &mut Vec<usize>| {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        };
        measure(&self.header, &mut widths);
        for row in &self.rows {
            measure(row, &mut widths);
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |row: &[String]| {
            let mut s = String::from("|");
            for (i, w) in widths.iter().enumerate() {
                let cell = row.get(i).map(String::as_str).unwrap_or("");
                s.push_str(&format!(" {:<width$} |", cell, width = w));
            }
            s
        };
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            out.push('\n');
            out.push_str(&sep);
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out
    }
}

/// Format helper: `12.345` -> `"12.35"`.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

/// Format helper with arbitrary precision.
pub fn fp(x: f64, digits: usize) -> String {
    format!("{x:.digits$}")
}

/// Format a speedup, e.g. `2.841x`.
pub fn speedup(x: f64) -> String {
    format!("{x:.3}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo").header(&["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("| name      | value |"), "{s}");
        assert!(s.contains("| long-name | 2.5   |"), "{s}");
        // all separator lines equal length
        let lens: Vec<usize> = s.lines().skip(1).map(|l| l.chars().count()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn handles_ragged_rows() {
        let mut t = Table::new("r").header(&["a", "b", "c"]);
        t.row(vec!["1".into()]);
        let s = t.render();
        assert!(s.contains("| 1 |"));
    }

    #[test]
    fn format_helpers() {
        assert_eq!(f2(12.345), "12.35"); // rounds
        assert_eq!(speedup(2.8411), "2.841x");
        assert_eq!(fp(1.23456, 3), "1.235");
    }
}
