//! Integration: the zero-allocation contract of the steady-state
//! serving path (PR 3 acceptance criterion).
//!
//! A counting global allocator wraps the system allocator; after a
//! warm-up flush has grown every session buffer to its steady-state
//! size, repeated `Session::infer_batch_into` calls must perform ZERO
//! heap allocations — on the dense reference fabric, the bit-sliced
//! planned fabric, and the bit-sliced fabric at pool width > 1 (PR 4):
//! the parallel executors pre-grow every lane's scratch on the caller
//! thread and hand work off through pre-sized atomics + a condvar, so
//! parallel dispatch adds no steady-state allocations either (the
//! counter is process-global, so worker-thread allocations would be
//! caught).
//!
//! A weight-streamed session (PR 6) cannot be allocation-free — every
//! reload pass rebuilds its weights — so its contract is *bounded*
//! steady state instead: the same allocation count every batch, with
//! no monotonic growth.
//!
//! This file deliberately contains a single `#[test]`: the counter is
//! process-global, and a concurrently running test would pollute the
//! measured window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use ddc_pim::runtime::{
    reference::{ReferenceBackend, StreamConfig},
    FabricChoice, Session, NUM_CLASSES,
};
use ddc_pim::util::rng::Rng;

/// System allocator wrapper counting every allocation-path call
/// (alloc, alloc_zeroed, realloc).  Deallocations are not counted:
/// freeing is allowed on the steady-state path only if nothing was
/// allocated to free.
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_infer_batch_into_is_allocation_free() {
    const IMG: usize = 32 * 32 * 3;
    // (fabric, pool width): width 4 exercises the parallel dispatch
    // path — per-lane ExecCtx clones kept warm, work handed off
    // allocation-free (explicit widths, not DDC_THREADS, so the
    // measured configuration never depends on the environment).  The
    // dense width-4 case covers the pooled MVM row-block kernels,
    // which dispatch through the same pre-sized atomics.
    let cases = [
        (FabricChoice::DenseReference, 1usize),
        (FabricChoice::DenseReference, 4),
        (FabricChoice::BitSliced, 1),
        (FabricChoice::BitSliced, 4),
    ];
    for (fabric, threads) in cases {
        let backend = ReferenceBackend::seeded_with(0xDDC0, fabric).with_threads(threads);
        let mut session = backend.plan().expect("plan");
        let batch = 4;
        let mut rng = Rng::new(77);
        let x: Vec<f32> = (0..batch * IMG).map(|_| rng.normal() as f32).collect();
        let mut out = vec![0f32; batch * NUM_CLASSES];
        // warm-up: the first flush grows every internal buffer to its
        // steady-state size (two rounds, in case any buffer is grown
        // lazily on a later layer)
        for _ in 0..2 {
            session.infer_batch_into(&x, batch, &mut out).expect("warm-up");
        }
        let before = ALLOC_CALLS.load(Ordering::SeqCst);
        for _ in 0..5 {
            session.infer_batch_into(&x, batch, &mut out).expect("steady");
        }
        let after = ALLOC_CALLS.load(Ordering::SeqCst);
        assert_eq!(
            after - before,
            0,
            "steady-state infer_batch_into allocated on the {fabric:?} path at {threads} threads"
        );
        // the outputs are real (not an accidentally-elided call)
        assert!(out.iter().any(|&v| v != 0.0), "logits all zero on {fabric:?}");
    }

    // streamed session: a 2304 B budget splits the seeded stack into 2
    // reload passes, so every batch rebuilds both passes' weights —
    // bounded, not zero.  Synchronous staging keeps the stager thread
    // (and its channel traffic) out of the measured window; the per-
    // batch allocation count must be identical across rounds.
    let backend = ReferenceBackend::seeded_with(0xDDC0, FabricChoice::BitSliced)
        .with_streaming(StreamConfig::synchronous(2304));
    let mut session = backend.plan().expect("streamed plan");
    let batch = 4;
    let mut rng = Rng::new(77);
    let x: Vec<f32> = (0..batch * IMG).map(|_| rng.normal() as f32).collect();
    let mut out = vec![0f32; batch * NUM_CLASSES];
    for _ in 0..2 {
        session.infer_batch_into(&x, batch, &mut out).expect("streamed warm-up");
    }
    let mut per_round = [0u64; 4];
    for slot in per_round.iter_mut() {
        let before = ALLOC_CALLS.load(Ordering::SeqCst);
        session.infer_batch_into(&x, batch, &mut out).expect("streamed steady");
        let after = ALLOC_CALLS.load(Ordering::SeqCst);
        *slot = after - before;
    }
    assert!(
        per_round.iter().all(|&c| c == per_round[0]),
        "streamed steady state must not grow: per-round allocation counts {per_round:?}"
    );
    assert!(out.iter().any(|&v| v != 0.0), "streamed logits all zero");
}
