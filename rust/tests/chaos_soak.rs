//! Integration: the chaos soak harness (PR 10 tentpole).
//!
//! A seeded schedule of runtime retention upsets, worker panics,
//! client-visible hangs and prefetch-stager kills, run for >= 200
//! batches — long enough for every repair path to fire many times —
//! with three hard acceptance gates:
//!
//! * **No corrupt logits, ever.**  Every answer served during the soak
//!   must be byte-identical to the fault-free oracle.  Upsets land
//!   between batches (tick → scrub → compute), so a full-coverage
//!   scrub budget means no corrupt stored bit can reach an MVM.
//! * **Availability.**  The serving tier must answer at least 90% of
//!   requests during the soak (in practice: all of them — panics are
//!   absorbed by catch-unwind + rebuild, hangs are far below the
//!   client deadline).
//! * **Counters reconcile.**  Every upset bit the process landed is
//!   found by a scrub (`upset_bits == corrupt_bits_found`); worker
//!   quarantines are matched one-for-one by clean-scrub rejoins and
//!   the cluster ends serving-capable.

use std::time::Duration;

use ddc_pim::arch::fault::UpsetConfig;
use ddc_pim::coordinator::{BatchPolicy, InferenceService, ServiceConfig};
use ddc_pim::runtime::reference::{ReferenceBackend, StreamConfig, DEFAULT_SEED};
use ddc_pim::runtime::{
    BackendKind, BackendSpec, FabricChoice, Session, IMG_ELEMS, NUM_CLASSES,
};
use ddc_pim::util::rng::Rng;

const SOAK_BATCHES: usize = 220;

fn probe_images(seed: u64, n: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..IMG_ELEMS).map(|_| rng.normal() as f32).collect())
        .collect()
}

/// Fault-free oracle logits for each probe image, from a pristine
/// bit-sliced session.
fn oracle_logits(imgs: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let be = ReferenceBackend::seeded_with(DEFAULT_SEED, FabricChoice::BitSliced);
    let mut s = be.plan().expect("oracle plan");
    imgs.iter()
        .map(|img| {
            let mut out = vec![0f32; NUM_CLASSES];
            s.infer_batch_into(img, 1, &mut out).expect("oracle infer");
            out
        })
        .collect()
}

#[test]
fn session_soak_under_continuous_upsets_never_serves_corruption() {
    // 220 batches of continuous upsets against a resident session with
    // the scrub at full coverage: byte-identity every batch, and exact
    // ledger reconciliation at the end (one tick outstanding per
    // boundary means no flip can cancel before its scrub sees it)
    let imgs = probe_images(0xC4_0501, 4);
    let want = oracle_logits(&imgs);
    let mut s = ReferenceBackend::seeded_with(DEFAULT_SEED, FabricChoice::BitSliced)
        .with_upsets(UpsetConfig::from_ppm(0xC4A05, 10_000))
        .with_scrub_stripes(usize::MAX)
        .plan()
        .expect("soak plan");
    let mut got = vec![0f32; NUM_CLASSES];
    for round in 0..SOAK_BATCHES {
        let k = round % imgs.len();
        s.infer_batch_into(&imgs[k], 1, &mut got).expect("soak infer");
        assert_eq!(got, want[k], "round {round}: corrupt logits served");
    }
    let r = s.reliability_stats();
    assert!(r.upset_bits > 0, "no upsets landed over {SOAK_BATCHES} batches");
    assert_eq!(
        r.upset_bits, r.corrupt_bits_found,
        "upset ledger did not reconcile: {r:?}"
    );
    assert_eq!(r.faults_injected, 0, "upsets-only soak has no write-time faults");
    assert_eq!(
        r.faults_repaired + r.zeroed_rows,
        r.quarantined_rows,
        "quarantine bookkeeping split drifted: {r:?}"
    );
    let (checked, total) = s.scrub_progress();
    assert_eq!(checked, (SOAK_BATCHES * total) as u64, "full coverage every boundary");
}

#[test]
fn streamed_session_soak_with_stager_kills_stays_byte_identical() {
    // the streamed variant: upsets age the resident pass only, and the
    // prefetch stager is killed mid-soak (degrading to synchronous
    // staging).  Byte-identity and reconciliation must both survive.
    let imgs = probe_images(0xC4_0502, 3);
    let be = ReferenceBackend::seeded_deep(DEFAULT_SEED, FabricChoice::BitSliced, 2);
    let mut o = be.plan().expect("oracle plan");
    let want: Vec<Vec<f32>> = imgs
        .iter()
        .map(|img| {
            let mut out = vec![0f32; NUM_CLASSES];
            o.infer_batch_into(img, 1, &mut out).expect("oracle infer");
            out
        })
        .collect();
    let mut s = ReferenceBackend::seeded_deep(DEFAULT_SEED, FabricChoice::BitSliced, 2)
        .with_streaming(StreamConfig::budget(9300))
        .with_upsets(UpsetConfig::from_ppm(0xC4A06, 10_000))
        .with_scrub_stripes(usize::MAX)
        .plan()
        .expect("streamed soak plan");
    assert_eq!(s.streaming_passes(), Some(2));
    let mut got = vec![0f32; NUM_CLASSES];
    let mut kills = 0;
    for round in 0..SOAK_BATCHES {
        if round == 70 && s.debug_kill_stager() {
            kills += 1;
        }
        let k = round % imgs.len();
        s.infer_batch_into(&imgs[k], 1, &mut got).expect("streamed soak infer");
        assert_eq!(got, want[k], "round {round}: corrupt streamed logits served");
    }
    assert_eq!(kills, 1, "the mid-soak stager kill must have found a live stager");
    let r = s.reliability_stats();
    assert!(r.upset_bits > 0, "no upsets landed on the resident pass");
    assert_eq!(
        r.upset_bits, r.corrupt_bits_found,
        "streamed upset ledger did not reconcile: {r:?}"
    );
    assert!(r.stager_fallbacks >= 1, "stager death must book a fallback");
}

#[test]
fn service_soak_with_panics_hangs_and_upsets_meets_the_availability_gate() {
    // the full serving-tier soak: 2 workers on the upset-ridden
    // bit-sliced fabric with full scrub coverage, a panic injected
    // roughly every 40 rounds (6 total — by pigeonhole some worker
    // takes two rebuilds and must quarantine + rejoin) and a short
    // hang roughly every 50.  Gates: byte-identity on every answer,
    // >= 90% availability, reconciled counters, cluster ends
    // serving-capable with quarantines matched by rejoins.
    let imgs = probe_images(0xC4_0503, 4);
    let want = oracle_logits(&imgs);
    let svc = InferenceService::start_cluster(
        BackendSpec {
            kind: BackendKind::Reference,
            fabric: FabricChoice::BitSliced,
            upset_ppm: 10_000,
            scrub_stripes: u32::MAX,
            ..Default::default()
        },
        "/nonexistent".into(),
        BatchPolicy::default(),
        ServiceConfig {
            workers: 2,
            max_queue_depth: 0,
        },
    );
    let mut served = 0usize;
    for round in 0..SOAK_BATCHES {
        if round % 40 == 3 {
            svc.debug_panic_next_batch();
        }
        if round % 50 == 17 {
            svc.debug_hang_next_batch(Duration::from_millis(3));
        }
        let k = round % imgs.len();
        match svc.infer(imgs[k].clone()) {
            Ok(r) => {
                assert_eq!(
                    r.logits[..],
                    want[k][..],
                    "round {round}: the service answered with corrupt logits"
                );
                served += 1;
            }
            // a fully parked pool sheds at the door; that costs
            // availability but must never corrupt an answer
            Err(e) => eprintln!("soak round {round} unanswered: {e}"),
        }
    }
    let availability = served as f64 / SOAK_BATCHES as f64;
    assert!(
        availability >= 0.9,
        "availability {availability:.3} below the 90% soak gate"
    );
    let s = svc.stats().expect("stats");
    let r = s.reliability;
    assert!(r.upset_bits > 0, "no upsets landed during the service soak");
    assert_eq!(
        r.upset_bits, r.corrupt_bits_found,
        "service upset ledger did not reconcile: {r:?}"
    );
    assert!(r.worker_rebuilds >= 2, "panics must have forced rebuilds");
    assert!(
        s.health.quarantine_events >= 1,
        "6 panics over 2 workers must quarantine someone: {:?}",
        s.health
    );
    assert_eq!(
        s.health.quarantine_events, s.health.rejoin_events,
        "every quarantine must resolve in a clean rejoin: {:?}",
        s.health
    );
    assert_eq!(
        s.health.healthy + s.health.degraded,
        s.admission.workers,
        "cluster did not end serving-capable: {:?}",
        s.health
    );
    assert_eq!(s.admission.shed_expired, 0, "nothing used deadlines short enough to expire");
}

#[test]
fn zero_upset_service_with_scrub_enabled_is_byte_identical_and_repair_free() {
    // the control arm: scrub on, nothing to find.  Served logits match
    // the oracle byte for byte and not a single repair is booked —
    // pure verification must be invisible.
    let imgs = probe_images(0xC4_0504, 2);
    let want = oracle_logits(&imgs);
    let svc = InferenceService::start_cluster(
        BackendSpec {
            kind: BackendKind::Reference,
            fabric: FabricChoice::BitSliced,
            scrub_stripes: 64,
            ..Default::default()
        },
        "/nonexistent".into(),
        BatchPolicy::default(),
        ServiceConfig {
            workers: 2,
            max_queue_depth: 0,
        },
    );
    for round in 0..8 {
        let k = round % imgs.len();
        let r = svc.infer(imgs[k].clone()).expect("scrubbed service serves");
        assert_eq!(r.logits[..], want[k][..], "round {round}: clean scrub changed logits");
    }
    let s = svc.stats().expect("stats");
    assert_eq!(s.reliability.upset_bits, 0);
    assert_eq!(s.reliability.faults_repaired, 0, "clean fabric booked repairs");
    assert_eq!(s.reliability.quarantined_rows, 0);
    assert!(
        s.reliability.scrub_stripes_checked > 0,
        "the scheduler never walked its budget"
    );
    assert_eq!(s.health.healthy, 2, "clean cluster must stay healthy: {:?}", s.health);
    assert_eq!(s.health.quarantine_events, 0);
}
