//! Differential coverage for the bitsliced fabric (PR 2 tentpole): the
//! word-parallel bit-plane `mvm_row` against the retained per-cell
//! scalar oracle across Regular/Double × Combined/Split × random INT8
//! inputs/weights and core geometries, and the zero-alloc executors
//! against direct convolution on random shapes.
//!
//! All cases are drawn from the seeded `util::rng` stream through the
//! `util::prop` harness, so any failure is replayable from the printed
//! seed.  (Under `--features scalar-fabric` the fabric dispatches to the
//! oracle itself and these tests pin the adapter instead.)

use ddc_pim::arch::lpu::Mode;
use ddc_pim::arch::pim_core::PimCore;
use ddc_pim::arch::pim_macro::{MvmScratch, PimMacro};
use ddc_pim::arch::reconfig::Grouping;
use ddc_pim::fcc::{fcc_transform, recompose, FilterBank};
use ddc_pim::mapping::exec::{exec_dw_fcc, exec_std_fcc};
use ddc_pim::mapping::im2col::{direct_conv, direct_dwconv};
use ddc_pim::util::prop::forall_explain;
use ddc_pim::util::rng::Rng;

fn random_macro(rng: &mut Rng, ncmp: usize, rows: usize) -> PimMacro {
    let mut mac = PimMacro::new(PimCore::new(ncmp, rows, 16), 8, 8);
    for cmp in 0..ncmp {
        for row in 0..rows {
            for slot in 0..2 {
                mac.load_weight(cmp, row, slot, rng.int8() as i32);
            }
        }
    }
    mac
}

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<i32> {
    (0..n).map(|_| rng.int8() as i32).collect()
}

/// Sparse INT8 vector: ~half the lanes zero, to exercise the all-zero
/// input bit-plane skip against the oracle (which never skips).
fn sparse_vec(rng: &mut Rng, n: usize) -> Vec<i32> {
    (0..n)
        .map(|_| if rng.below(2) == 0 { 0 } else { rng.int8() as i32 })
        .collect()
}

#[test]
fn bitsliced_mvm_row_matches_scalar_oracle() {
    forall_explain(
        0xB175_11CE,
        40,
        |r| {
            let ncmp = [2usize, 8, 16, 32][r.below(4) as usize];
            let rows = 1 + r.below(4) as usize;
            (ncmp, rows, r.next_u64())
        },
        |&(ncmp, rows, seed)| {
            let mut rng = Rng::new(seed);
            let mac = random_macro(&mut rng, ncmp, rows);
            let xs = rand_vec(&mut rng, ncmp);
            let xn = sparse_vec(&mut rng, ncmp);
            let mut scratch = MvmScratch::new();
            for row in 0..rows {
                for mode in [Mode::Regular, Mode::Double] {
                    for grouping in [Grouping::Combined, Grouping::Split] {
                        let want = mac.mvm_row_scalar(row, &xs, &xn, mode, grouping);
                        mac.mvm_row_into(row, &xs, &xn, mode, grouping, &mut scratch);
                        let got = scratch.to_vecs();
                        if got != want {
                            return Err(format!(
                                "divergence at row {row} {mode:?} {grouping:?} \
                                 (ncmp={ncmp}): {got:?} != {want:?}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn bitsliced_zero_extension_matches_padded_oracle() {
    // executors stream short im2col tails: lanes past the slice end must
    // behave exactly like explicit zero inputs in the scalar fabric
    forall_explain(
        0xB175_22,
        60,
        |r| {
            let len = r.below(33) as usize; // 0..=32 active lanes
            (len, r.next_u64())
        },
        |&(len, seed)| {
            let mut rng = Rng::new(seed);
            let mac = random_macro(&mut rng, 32, 2);
            let xs = rand_vec(&mut rng, len);
            let mut padded = xs.clone();
            padded.resize(32, 0);
            let mut scratch = MvmScratch::new();
            for grouping in [Grouping::Combined, Grouping::Split] {
                mac.mvm_row_into(1, &xs, &xs, Mode::Double, grouping, &mut scratch);
                let want = mac.mvm_row_scalar(1, &padded, &padded, Mode::Double, grouping);
                if scratch.to_vecs() != want {
                    return Err(format!("zero-extension drift at len={len} {grouping:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn exec_std_fcc_matches_direct_conv_on_random_shapes() {
    forall_explain(
        0xFCC_57D,
        12,
        |r| {
            let h = 2 + r.below(4) as usize;
            let w = 2 + r.below(4) as usize;
            let c = 1 + r.below(6) as usize;
            let k = [1usize, 3][r.below(2) as usize];
            let n = 2 * (1 + r.below(4) as usize);
            let stride = 1 + r.below(2) as usize;
            (h, w, c, k, n, stride, r.next_u64())
        },
        |&(h, w, c, k, n, stride, seed)| {
            let mut rng = Rng::new(seed);
            let input = rand_vec(&mut rng, h * w * c);
            let l = k * k * c;
            let bank = FilterBank::new(rand_vec(&mut rng, n * l), n, l);
            let fcc = fcc_transform(&bank);
            let got = exec_std_fcc(&input, h, w, c, &fcc, k, stride);
            // ground truth: direct conv with the recomposed biased-comp
            // bank (twice the stored filters)
            let want = direct_conv(&input, h, w, c, &recompose(&fcc).data, n, k, stride);
            if got == want {
                Ok(())
            } else {
                Err(format!("exec_std_fcc != direct conv at {h}x{w}x{c} k{k} n{n} s{stride}"))
            }
        },
    );
}

#[test]
fn exec_dw_fcc_matches_direct_dwconv_on_random_shapes() {
    forall_explain(
        0xD_FCC,
        12,
        |r| {
            let h = 2 + r.below(4) as usize;
            let w = 2 + r.below(4) as usize;
            let c = 2 * (1 + r.below(8) as usize);
            let stride = 1 + r.below(2) as usize;
            let reconfig = r.below(2) == 1;
            (h, w, c, stride, reconfig, r.next_u64())
        },
        |&(h, w, c, stride, reconfig, seed)| {
            let k = 3;
            let mut rng = Rng::new(seed);
            let input = rand_vec(&mut rng, h * w * c);
            let bank = FilterBank::new(rand_vec(&mut rng, c * k * k), c, k * k);
            let fcc = fcc_transform(&bank);
            let got = exec_dw_fcc(&input, h, w, c, &fcc, k, stride, reconfig);
            let want = direct_dwconv(&input, h, w, c, &recompose(&fcc).data, k, stride);
            if got == want {
                Ok(())
            } else {
                Err(format!(
                    "exec_dw_fcc != direct dwconv at {h}x{w}x{c} s{stride} reconfig={reconfig}"
                ))
            }
        },
    );
}
