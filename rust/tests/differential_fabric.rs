//! Differential coverage for the bitsliced fabric (PR 2 tentpole,
//! extended by the PR 5 multi-word planes): the word-parallel bit-plane
//! `mvm_row` against the retained per-cell scalar oracle across
//! Regular/Double × Combined/Split × random INT8 inputs/weights and
//! core geometries — including >64-compartment multi-word geometries
//! and adversarial sparse/dense weight patterns aimed at the nonzero
//! summaries — and the zero-alloc executors against direct convolution
//! on random shapes.
//!
//! All cases are drawn from the seeded `util::rng` stream through the
//! `util::prop` harness, so any failure is replayable from the printed
//! seed.  (Under `--features scalar-fabric` the fabric dispatches to the
//! oracle itself and these tests pin the adapter instead.)

use ddc_pim::arch::fault::FaultPlan;
use ddc_pim::arch::lpu::Mode;
use ddc_pim::arch::pim_core::{MacroGeometry, PimCore};
use ddc_pim::arch::pim_macro::{MvmScratch, PimMacro};
use ddc_pim::arch::reconfig::Grouping;
use ddc_pim::fcc::{fcc_transform, recompose, FilterBank};
use ddc_pim::mapping::exec::{exec_dw_fcc, exec_std_fcc, ExecCtx, PlannedConv, PlannedDwConv};
use ddc_pim::mapping::im2col::{direct_conv, direct_dwconv};
use ddc_pim::util::prop::forall_explain;
use ddc_pim::util::rng::Rng;

fn random_macro(rng: &mut Rng, ncmp: usize, rows: usize) -> PimMacro {
    let mut mac = PimMacro::new(PimCore::new(ncmp, rows, 16), 8, 8);
    for cmp in 0..ncmp {
        for row in 0..rows {
            for slot in 0..2 {
                mac.load_weight(cmp, row, slot, rng.int8() as i32);
            }
        }
    }
    mac
}

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<i32> {
    (0..n).map(|_| rng.int8() as i32).collect()
}

/// Sparse INT8 vector: ~half the lanes zero, to exercise the all-zero
/// input bit-plane skip against the oracle (which never skips).
fn sparse_vec(rng: &mut Rng, n: usize) -> Vec<i32> {
    (0..n)
        .map(|_| if rng.below(2) == 0 { 0 } else { rng.int8() as i32 })
        .collect()
}

#[test]
fn bitsliced_mvm_row_matches_scalar_oracle() {
    forall_explain(
        0xB175_11CE,
        40,
        |r| {
            let ncmp = [2usize, 8, 16, 32][r.below(4) as usize];
            let rows = 1 + r.below(4) as usize;
            (ncmp, rows, r.next_u64())
        },
        |&(ncmp, rows, seed)| {
            let mut rng = Rng::new(seed);
            let mac = random_macro(&mut rng, ncmp, rows);
            let xs = rand_vec(&mut rng, ncmp);
            let xn = sparse_vec(&mut rng, ncmp);
            let mut scratch = MvmScratch::new();
            for row in 0..rows {
                for mode in [Mode::Regular, Mode::Double] {
                    for grouping in [Grouping::Combined, Grouping::Split] {
                        let want = mac.mvm_row_scalar(row, &xs, &xn, mode, grouping);
                        mac.mvm_row_into(row, &xs, &xn, mode, grouping, &mut scratch);
                        let got = scratch.to_vecs();
                        if got != want {
                            return Err(format!(
                                "divergence at row {row} {mode:?} {grouping:?} \
                                 (ncmp={ncmp}): {got:?} != {want:?}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn empty_fault_plan_is_byte_identical_across_geometries() {
    // the fault-interposed write path with an *empty* plan must be a
    // provable no-op: a macro with FaultPlan::empty() installed and a
    // plain macro, loaded from the same weight stream, must agree on
    // every readout across Regular/Double × Combined/Split — including
    // multi-word (>64 compartment) geometries
    forall_explain(
        0xFA_0017,
        24,
        |r| {
            let ncmp = [16usize, 32, 64, 65, 96, 128][r.below(6) as usize];
            let rows = 1 + r.below(4) as usize;
            (ncmp, rows, r.next_u64())
        },
        |&(ncmp, rows, seed)| {
            let mut rng = Rng::new(seed);
            let plain = random_macro(&mut rng, ncmp, rows);
            let xs = rand_vec(&mut rng, ncmp);
            let xn = sparse_vec(&mut rng, ncmp);
            // identical weight stream into a fault-interposed core
            let mut rng2 = Rng::new(seed);
            let mut faulted = PimMacro::new(PimCore::new(ncmp, rows, 16), 8, 8);
            faulted.core.install_fault_plan(&FaultPlan::empty());
            for cmp in 0..ncmp {
                for row in 0..rows {
                    for slot in 0..2 {
                        faulted.load_weight(cmp, row, slot, rng2.int8() as i32);
                    }
                }
            }
            let mut sa = MvmScratch::new();
            let mut sb = MvmScratch::new();
            for row in 0..rows {
                for mode in [Mode::Regular, Mode::Double] {
                    for grouping in [Grouping::Combined, Grouping::Split] {
                        plain.mvm_row_into(row, &xs, &xn, mode, grouping, &mut sa);
                        faulted.mvm_row_into(row, &xs, &xn, mode, grouping, &mut sb);
                        if sa.to_vecs() != sb.to_vecs() {
                            return Err(format!(
                                "empty fault plan changed row {row} {mode:?} {grouping:?} \
                                 (ncmp={ncmp})"
                            ));
                        }
                    }
                }
            }
            // the scrub on an uncorrupted core must find nothing
            let report = faulted.core.scrub();
            if !report.is_clean() {
                return Err(format!("clean-core scrub reported damage: {report:?}"));
            }
            Ok(())
        },
    );
}

/// Every (row, mode, grouping) of a macro vs the scalar oracle; returns
/// the first divergence as an error string.
fn check_macro_vs_oracle(
    mac: &PimMacro,
    rows: usize,
    xs: &[i32],
    xn: &[i32],
    label: &str,
) -> Result<(), String> {
    let mut scratch = MvmScratch::new();
    for row in 0..rows {
        for mode in [Mode::Regular, Mode::Double] {
            for grouping in [Grouping::Combined, Grouping::Split] {
                let want = mac.mvm_row_scalar(row, xs, xn, mode, grouping);
                mac.mvm_row_into(row, xs, xn, mode, grouping, &mut scratch);
                if scratch.to_vecs() != want {
                    return Err(format!("divergence at row {row} {mode:?} {grouping:?} ({label})"));
                }
            }
        }
    }
    Ok(())
}

#[test]
fn multiword_geometries_match_scalar_oracle() {
    // >64 compartments — 65 (one lane into the second word), 96, 128 —
    // were hard-rejected by the single-word WeightPlanes; now they must
    // be bit-true across every mode and grouping
    forall_explain(
        0x71DE_1A85,
        12,
        |r| {
            let ncmp = [65usize, 96, 128][r.below(3) as usize];
            (ncmp, r.next_u64())
        },
        |&(ncmp, seed)| {
            let mut rng = Rng::new(seed);
            let mac = random_macro(&mut rng, ncmp, 2);
            let xs = rand_vec(&mut rng, ncmp);
            let xn = sparse_vec(&mut rng, ncmp);
            check_macro_vs_oracle(&mac, 2, &xs, &xn, &format!("ncmp={ncmp}"))
        },
    );
}

#[test]
fn adversarial_weight_patterns_match_scalar_oracle() {
    // stored-weight patterns aimed at the per-word nonzero summaries:
    // all-zero (every Q plane dark, every Q̄ plane lit), all -1 (the
    // inverse), {0, 1} (Q sparse / Q̄ dense), {-1, -2} (Q̄ sparse),
    // a single hot lane, and a single hot weight bit — across narrow,
    // word-boundary and multi-word lane counts, against dense INP and
    // half-zero INN inputs
    forall_explain(
        0xDA2_B175,
        48,
        |r| {
            let ncmp = [16usize, 32, 64, 65, 128][r.below(5) as usize];
            let pat = r.below(6) as usize;
            (ncmp, pat, r.next_u64())
        },
        |&(ncmp, pat, seed)| {
            let mut rng = Rng::new(seed);
            let mut mac = PimMacro::new(PimCore::new(ncmp, 2, 16), 8, 8);
            let hot_lane = rng.below(ncmp as u64) as usize;
            for cmp in 0..ncmp {
                for row in 0..2 {
                    for slot in 0..2 {
                        let w = match pat {
                            0 => 0,
                            1 => -1,
                            2 => rng.below(2) as i32,
                            3 => -1 - rng.below(2) as i32,
                            4 if cmp == hot_lane => rng.int8() as i32,
                            4 => 0,
                            _ => (rng.below(2) as i32) << 5, // only kw=5 ever lit
                        };
                        mac.load_weight(cmp, row, slot, w);
                    }
                }
            }
            let xs = rand_vec(&mut rng, ncmp);
            let xn = sparse_vec(&mut rng, ncmp);
            check_macro_vs_oracle(&mac, 2, &xs, &xn, &format!("ncmp={ncmp} pattern={pat}"))
        },
    );
}

#[test]
fn wide_zero_extension_matches_padded_oracle() {
    // short input slices on a 128-lane macro: lanes past the slice end
    // (including entire upper words) must behave like explicit zeros
    forall_explain(
        0x71DE_22,
        24,
        |r| {
            let len = r.below(129) as usize; // 0..=128 active lanes
            (len, r.next_u64())
        },
        |&(len, seed)| {
            let mut rng = Rng::new(seed);
            let mac = random_macro(&mut rng, 128, 2);
            let xs = rand_vec(&mut rng, len);
            let mut padded = xs.clone();
            padded.resize(128, 0);
            let mut scratch = MvmScratch::new();
            for grouping in [Grouping::Combined, Grouping::Split] {
                mac.mvm_row_into(1, &xs, &xs, Mode::Double, grouping, &mut scratch);
                let want = mac.mvm_row_scalar(1, &padded, &padded, Mode::Double, grouping);
                if scratch.to_vecs() != want {
                    return Err(format!("wide zero-extension drift at len={len} {grouping:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn wide_geometry_executors_match_direct_conv() {
    // the plan/execute path at 65/96/128 compartments (std FCC and dw
    // reconfig) against the direct-conv oracles
    forall_explain(
        0x71DE_57D,
        9,
        |r| {
            let lanes = [65usize, 96, 128][r.below(3) as usize];
            (lanes, r.next_u64())
        },
        |&(lanes, seed)| {
            let geom = MacroGeometry::with_compartments(lanes);
            let mut rng = Rng::new(seed);
            let (h, w, c, k, n) = (4usize, 3usize, 11usize, 3usize, 6usize);
            let input = rand_vec(&mut rng, h * w * c);
            let l = k * k * c; // 99: tiles 1-2 words wide, ragged tail
            let bank = FilterBank::new(rand_vec(&mut rng, n * l), n, l);
            let fcc = fcc_transform(&bank);
            let plan = PlannedConv::std_fcc_with(geom, h, w, c, &fcc, k, 1);
            let mut ctx = ExecCtx::new();
            let mut out = vec![0i64; plan.out_len()];
            plan.execute(&input, &mut ctx, &mut out);
            let want = direct_conv(&input, h, w, c, &recompose(&fcc).data, n, k, 1);
            if out != want {
                return Err(format!("std_fcc_with drifted at {lanes} lanes"));
            }
            let dc = 8usize;
            let dw_input = rand_vec(&mut rng, h * w * dc);
            let dw_bank = FilterBank::new(rand_vec(&mut rng, dc * k * k), dc, k * k);
            let dw_fcc = fcc_transform(&dw_bank);
            let dw_plan = PlannedDwConv::fcc_with(geom, h, w, dc, &dw_fcc, k, 1, true);
            let mut dw_out = vec![0i64; dw_plan.out_len()];
            dw_plan.execute(&dw_input, &mut ctx, &mut dw_out);
            let dw_want = direct_dwconv(&dw_input, h, w, dc, &recompose(&dw_fcc).data, k, 1);
            if dw_out != dw_want {
                return Err(format!("dw fcc_with drifted at {lanes} lanes"));
            }
            Ok(())
        },
    );
}

#[test]
fn bitsliced_zero_extension_matches_padded_oracle() {
    // executors stream short im2col tails: lanes past the slice end must
    // behave exactly like explicit zero inputs in the scalar fabric
    forall_explain(
        0xB175_22,
        60,
        |r| {
            let len = r.below(33) as usize; // 0..=32 active lanes
            (len, r.next_u64())
        },
        |&(len, seed)| {
            let mut rng = Rng::new(seed);
            let mac = random_macro(&mut rng, 32, 2);
            let xs = rand_vec(&mut rng, len);
            let mut padded = xs.clone();
            padded.resize(32, 0);
            let mut scratch = MvmScratch::new();
            for grouping in [Grouping::Combined, Grouping::Split] {
                mac.mvm_row_into(1, &xs, &xs, Mode::Double, grouping, &mut scratch);
                let want = mac.mvm_row_scalar(1, &padded, &padded, Mode::Double, grouping);
                if scratch.to_vecs() != want {
                    return Err(format!("zero-extension drift at len={len} {grouping:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn exec_std_fcc_matches_direct_conv_on_random_shapes() {
    forall_explain(
        0xFCC_57D,
        12,
        |r| {
            let h = 2 + r.below(4) as usize;
            let w = 2 + r.below(4) as usize;
            let c = 1 + r.below(6) as usize;
            let k = [1usize, 3][r.below(2) as usize];
            let n = 2 * (1 + r.below(4) as usize);
            let stride = 1 + r.below(2) as usize;
            (h, w, c, k, n, stride, r.next_u64())
        },
        |&(h, w, c, k, n, stride, seed)| {
            let mut rng = Rng::new(seed);
            let input = rand_vec(&mut rng, h * w * c);
            let l = k * k * c;
            let bank = FilterBank::new(rand_vec(&mut rng, n * l), n, l);
            let fcc = fcc_transform(&bank);
            let got = exec_std_fcc(&input, h, w, c, &fcc, k, stride);
            // ground truth: direct conv with the recomposed biased-comp
            // bank (twice the stored filters)
            let want = direct_conv(&input, h, w, c, &recompose(&fcc).data, n, k, stride);
            if got == want {
                Ok(())
            } else {
                Err(format!("exec_std_fcc != direct conv at {h}x{w}x{c} k{k} n{n} s{stride}"))
            }
        },
    );
}

#[test]
fn exec_dw_fcc_matches_direct_dwconv_on_random_shapes() {
    forall_explain(
        0xD_FCC,
        12,
        |r| {
            let h = 2 + r.below(4) as usize;
            let w = 2 + r.below(4) as usize;
            let c = 2 * (1 + r.below(8) as usize);
            let stride = 1 + r.below(2) as usize;
            let reconfig = r.below(2) == 1;
            (h, w, c, stride, reconfig, r.next_u64())
        },
        |&(h, w, c, stride, reconfig, seed)| {
            let k = 3;
            let mut rng = Rng::new(seed);
            let input = rand_vec(&mut rng, h * w * c);
            let bank = FilterBank::new(rand_vec(&mut rng, c * k * k), c, k * k);
            let fcc = fcc_transform(&bank);
            let got = exec_dw_fcc(&input, h, w, c, &fcc, k, stride, reconfig);
            let want = direct_dwconv(&input, h, w, c, &recompose(&fcc).data, k, stride);
            if got == want {
                Ok(())
            } else {
                Err(format!(
                    "exec_dw_fcc != direct dwconv at {h}x{w}x{c} s{stride} reconfig={reconfig}"
                ))
            }
        },
    );
}
