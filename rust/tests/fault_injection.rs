//! Integration: the fault-injection + integrity-scrub subsystem (PR 7).
//!
//! Three layers of coverage:
//!
//! * **Core** — seeded stuck-at/transient sweeps on a [`PimCore`] with
//!   spare rows: every row the injected faults actually corrupted must
//!   be detected by the Q/Q̄-checksum scrub (the analytical bound: the
//!   Q̄ polarity is derived from Q, so a checksum over the stored Q
//!   planes covers every manifested fault), then either re-homed onto a
//!   verified-clean spare — restoring the written intent exactly — or
//!   zeroed whole (graceful degradation, never served corrupt).
//! * **Determinism** — the same seed must produce the same faults, the
//!   same quarantine decisions, the same spare assignments, and the
//!   same post-scrub reads, twice.
//! * **Session** — zero-fault sessions are byte-identical to the plain
//!   fabric on both fabrics; faulted sessions serve deterministically
//!   (streamed rebuilds are identically faulted); killing the prefetch
//!   stager mid-run degrades to synchronous staging with byte-identical
//!   logits and a booked fallback, never a panic or a hung recv.

use ddc_pim::arch::fault::{FaultConfig, FaultPlan, UpsetConfig};
use ddc_pim::arch::pim_core::{MacroGeometry, PimCore};
use ddc_pim::runtime::reference::{ReferenceBackend, StreamConfig, DEFAULT_SEED};
use ddc_pim::runtime::{FabricChoice, Session, IMG_ELEMS, NUM_CLASSES};
use ddc_pim::util::prop::forall_explain;
use ddc_pim::util::rng::Rng;

const NCMP: usize = 8;
const ROWS: usize = 8;
const WRITTEN: usize = 4; // rows loaded with weights; the rest are spares
const SLOTS: usize = 2;

/// Build a core under a seeded fault plan, write a deterministic weight
/// pattern into the first [`WRITTEN`] rows, and return it with the
/// intended values (indexed `[cmp][row][slot]`, flattened).
fn faulted_core(cfg: &FaultConfig, wseed: u64) -> (PimCore, Vec<i32>) {
    let geom = MacroGeometry {
        compartments: NCMP,
        rows: ROWS,
        dbmus: 16,
    };
    let mut core = PimCore::with_geometry(geom);
    core.install_fault_plan(&FaultPlan::seeded(geom, cfg, 0));
    let mut rng = Rng::new(wseed);
    let mut intents = vec![0i32; NCMP * WRITTEN * SLOTS];
    for cmp in 0..NCMP {
        for row in 0..WRITTEN {
            for slot in 0..SLOTS {
                let w = rng.int8() as i32;
                intents[(cmp * WRITTEN + row) * SLOTS + slot] = w;
                core.write_weight(cmp, row, slot, w);
            }
        }
    }
    (core, intents)
}

/// Rows (logical) whose current reads diverge from the written intent.
fn corrupt_rows(core: &PimCore, intents: &[i32]) -> Vec<usize> {
    (0..WRITTEN)
        .filter(|&row| {
            (0..NCMP).any(|cmp| {
                (0..SLOTS).any(|slot| {
                    core.read_weight(cmp, row, slot)
                        != intents[(cmp * WRITTEN + row) * SLOTS + slot]
                })
            })
        })
        .collect()
}

#[test]
fn seeded_sweeps_are_fully_detected_then_repaired_or_zeroed() {
    forall_explain(
        0xFA_D37C,
        16,
        |r| (r.next_u64(), r.next_u64()),
        |&(fseed, wseed)| {
            let cfg = FaultConfig::new(fseed, 0.02);
            let (mut core, intents) = faulted_core(&cfg, wseed);
            // analytical detection bound: every row the write path
            // actually corrupted must be quarantined by the scrub —
            // the checksum covers the full stored Q state, and Q̄ is
            // derived, so no manifested fault can hide
            let corrupt = corrupt_rows(&core, &intents);
            let report = core.scrub();
            if report.quarantined_rows != corrupt.len() as u64 {
                return Err(format!(
                    "scrub quarantined {} rows, but {} rows were corrupt: {corrupt:?}",
                    report.quarantined_rows,
                    corrupt.len()
                ));
            }
            if report.repaired_rows + report.zeroed_rows != report.quarantined_rows {
                return Err(format!("quarantine bookkeeping split drifted: {report:?}"));
            }
            // post-scrub serving contract: every written row either
            // reads back its intent exactly (repaired, or never hit) or
            // is fully zeroed (degraded) — corrupt data is never served
            for row in 0..WRITTEN {
                let reads: Vec<i32> = (0..NCMP)
                    .flat_map(|cmp| (0..SLOTS).map(move |slot| (cmp, slot)))
                    .map(|(cmp, slot)| core.read_weight(cmp, row, slot))
                    .collect();
                let wants: Vec<i32> = (0..NCMP)
                    .flat_map(|cmp| (0..SLOTS).map(move |slot| (cmp, slot)))
                    .map(|(cmp, slot)| intents[(cmp * WRITTEN + row) * SLOTS + slot])
                    .collect();
                let intact = reads == wants;
                let zeroed = reads.iter().all(|&v| v == 0);
                if !intact && !zeroed {
                    return Err(format!(
                        "row {row} serves corrupt data after scrub: {reads:?} != {wants:?}"
                    ));
                }
            }
            // a second scrub over the repaired state finds nothing new
            let second = core.scrub();
            if !second.is_clean() {
                return Err(format!("second scrub not clean: {second:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn quarantine_and_repair_are_deterministic() {
    forall_explain(
        0xDE_7E12,
        10,
        |r| (r.next_u64(), r.next_u64()),
        |&(fseed, wseed)| {
            let cfg = FaultConfig::new(fseed, 0.03);
            let (mut a, _) = faulted_core(&cfg, wseed);
            let (mut b, _) = faulted_core(&cfg, wseed);
            let ra = a.scrub();
            let rb = b.scrub();
            if ra != rb {
                return Err(format!("scrub reports diverged: {ra:?} != {rb:?}"));
            }
            if a.fault_tally() != b.fault_tally() {
                return Err("fault tallies diverged".into());
            }
            for row in 0..WRITTEN {
                if a.physical_row(row) != b.physical_row(row) {
                    return Err(format!(
                        "row {row} re-homed differently: {} vs {}",
                        a.physical_row(row),
                        b.physical_row(row)
                    ));
                }
                for cmp in 0..NCMP {
                    for slot in 0..SLOTS {
                        if a.read_weight(cmp, row, slot) != b.read_weight(cmp, row, slot) {
                            return Err(format!("post-scrub read diverged at ({cmp},{row},{slot})"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

fn batch_input(seed: u64, batch: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..batch * IMG_ELEMS).map(|_| rng.normal() as f32).collect()
}

#[test]
fn zero_fault_sessions_are_byte_identical_on_both_fabrics() {
    // BER 0 must be indistinguishable from no fault model at all —
    // the zero-fault byte-identity acceptance gate, at session level
    let x = batch_input(0xFA_B17E, 2);
    for fabric in [FabricChoice::DenseReference, FabricChoice::BitSliced] {
        let plain = ReferenceBackend::seeded_with(DEFAULT_SEED, fabric);
        let faulted = ReferenceBackend::seeded_with(DEFAULT_SEED, fabric)
            .with_faults(FaultConfig::new(9, 0.0));
        let mut want = vec![0f32; 2 * NUM_CLASSES];
        let mut got = vec![0f32; 2 * NUM_CLASSES];
        plain.plan().expect("plain").infer_batch_into(&x, 2, &mut want).expect("plain infer");
        let mut fs = faulted.plan().expect("faulted plan");
        fs.infer_batch_into(&x, 2, &mut got).expect("faulted infer");
        assert_eq!(got, want, "zero-BER fault model changed logits on {fabric:?}");
        let r = fs.reliability_stats();
        assert!(r.is_quiet(), "zero-BER session booked events on {fabric:?}: {r:?}");
    }
}

#[test]
fn faulted_streamed_rebuild_is_identically_faulted() {
    // streaming rebuilds pass macros from scratch every reload: the
    // per-layer fault derivation must make every rebuild identical, so
    // a faulted streamed session is deterministic across rounds — and
    // agrees with the faulted *resident* session, which built each
    // macro exactly once
    let x = batch_input(0xFA_57E4, 1);
    let cfg = FaultConfig::new(41, 0.001);
    let mut resident = ReferenceBackend::seeded_deep(DEFAULT_SEED, FabricChoice::BitSliced, 2)
        .with_faults(cfg)
        .plan()
        .expect("resident plan");
    let mut want = vec![0f32; NUM_CLASSES];
    resident.infer_batch_into(&x, 1, &mut want).expect("resident infer");
    let mut streamed = ReferenceBackend::seeded_deep(DEFAULT_SEED, FabricChoice::BitSliced, 2)
        .with_faults(cfg)
        .with_streaming(StreamConfig::budget(9300))
        .plan()
        .expect("streamed plan");
    assert_eq!(streamed.streaming_passes(), Some(2));
    let mut got = vec![0f32; NUM_CLASSES];
    for round in 0..3 {
        streamed.infer_batch_into(&x, 1, &mut got).expect("streamed infer");
        assert_eq!(got, want, "faulted streamed logits drifted from resident (round {round})");
    }
    let r = streamed.reliability_stats();
    assert!(r.faults_injected > 0, "BER 0.001 on the deep stack injected nothing");
}

#[test]
fn killed_stager_falls_back_to_synchronous_staging_byte_identically() {
    // chaos: the stager thread dies mid-run.  The session must log a
    // fallback, stage synchronously from then on, and keep producing
    // logits byte-identical to the resident session — no expect-panic,
    // no hung recv
    let x = batch_input(0xFA_C4A0, 2);
    let mut resident = ReferenceBackend::seeded_deep(DEFAULT_SEED, FabricChoice::BitSliced, 2)
        .plan()
        .expect("resident plan");
    let mut want = vec![0f32; 2 * NUM_CLASSES];
    resident.infer_batch_into(&x, 2, &mut want).expect("resident infer");

    let mut s = ReferenceBackend::seeded_deep(DEFAULT_SEED, FabricChoice::BitSliced, 2)
        .with_streaming(StreamConfig::budget(9300))
        .plan()
        .expect("streamed plan");
    let mut got = vec![0f32; 2 * NUM_CLASSES];
    s.infer_batch_into(&x, 2, &mut got).expect("infer before kill");
    assert_eq!(got, want, "streamed logits drifted before the kill");

    assert!(s.debug_kill_stager(), "prefetching session should have a live stager");
    // the next pass acquisition discovers the death and falls back
    for round in 0..2 {
        s.infer_batch_into(&x, 2, &mut got).expect("infer after kill");
        assert_eq!(got, want, "logits drifted after stager death (round {round})");
    }
    let r = s.reliability_stats();
    assert!(
        r.stager_fallbacks >= 1,
        "stager death must book a fallback, got {r:?}"
    );
    // killing an already-dead stager is a no-op
    assert!(!s.debug_kill_stager());
}

#[test]
fn runtime_upsets_with_full_scrub_serve_the_fault_free_logits() {
    // runtime retention upsets land between batches; with the
    // incremental scrub at full coverage (tick → scrub → compute) no
    // corrupt stored bit can reach an MVM, so every batch is
    // byte-identical to the fault-free oracle — and the upset ledger
    // reconciles exactly: every landed bit was found by a scrub
    let x = batch_input(0xFA_0757, 1);
    let mut oracle = ReferenceBackend::seeded_with(DEFAULT_SEED, FabricChoice::BitSliced)
        .plan()
        .expect("oracle plan");
    let mut want = vec![0f32; NUM_CLASSES];
    oracle.infer_batch_into(&x, 1, &mut want).expect("oracle infer");

    let mut s = ReferenceBackend::seeded_with(DEFAULT_SEED, FabricChoice::BitSliced)
        .with_upsets(UpsetConfig::from_ppm(0xC0DE, 20_000))
        .with_scrub_stripes(usize::MAX) // full coverage every boundary
        .plan()
        .expect("upset plan");
    let mut got = vec![0f32; NUM_CLASSES];
    for round in 0..8 {
        s.infer_batch_into(&x, 1, &mut got).expect("upset infer");
        assert_eq!(got, want, "round {round}: upsets leaked into served logits");
    }
    let r = s.reliability_stats();
    assert!(r.upset_bits > 0, "20000 ppm/batch over 8 batches landed nothing");
    assert_eq!(
        r.upset_bits, r.corrupt_bits_found,
        "full-coverage scrub must reconcile the upset ledger exactly"
    );
    assert_eq!(r.faults_injected, 0, "upsets-only config has no write-time faults");
    assert!(r.faults_repaired > 0, "found corruption was never repaired");
    let (checked, total) = s.scrub_progress();
    assert!(total > 0, "no stripe space despite armed scrub");
    assert_eq!(checked, 8 * total as u64, "full budget must sweep the space each batch");
}

#[test]
fn zero_upset_scrub_is_byte_identical_and_books_no_repairs() {
    // scrub enabled, nothing to find: pure verification overhead must
    // not perturb logits or book a single reliability event beyond the
    // checked-stripe progress counters
    let x = batch_input(0xFA_00AB, 2);
    let mut plain = ReferenceBackend::seeded_with(DEFAULT_SEED, FabricChoice::BitSliced)
        .plan()
        .expect("plain plan");
    let mut want = vec![0f32; 2 * NUM_CLASSES];
    plain.infer_batch_into(&x, 2, &mut want).expect("plain infer");

    let mut s = ReferenceBackend::seeded_with(DEFAULT_SEED, FabricChoice::BitSliced)
        .with_scrub_stripes(64)
        .plan()
        .expect("scrubbed plan");
    let mut got = vec![0f32; 2 * NUM_CLASSES];
    for _ in 0..4 {
        s.infer_batch_into(&x, 2, &mut got).expect("scrubbed infer");
        assert_eq!(got, want, "a clean scrub changed served logits");
    }
    let r = s.reliability_stats();
    assert_eq!(r.upset_bits, 0);
    assert_eq!(r.corrupt_bits_found, 0);
    assert_eq!(r.faults_detected, 0, "clean fabric produced detections");
    assert_eq!(r.faults_repaired, 0, "clean fabric booked repairs");
    assert_eq!(r.quarantined_rows, 0);
    // the scheduler walked its budget every boundary regardless
    let (checked, total) = s.scrub_progress();
    assert!(total > 0);
    assert_eq!(checked, 4 * 64.min(total) as u64);
}

#[test]
fn partial_scrub_budget_converges_and_never_overcounts() {
    // a budget far below the stripe space: coverage takes
    // ceil(total/budget) batches per sweep.  Multi-tick accumulation
    // can cancel bit flips pairwise before a scrub visits the stripe,
    // so found <= landed; a final full scrub leaves the fabric clean.
    let x = batch_input(0xFA_9C4B, 1);
    let budget = 7usize;
    let mut s = ReferenceBackend::seeded_with(DEFAULT_SEED, FabricChoice::BitSliced)
        .with_upsets(UpsetConfig::from_ppm(0x5EED, 5_000))
        .with_scrub_stripes(budget)
        .plan()
        .expect("plan");
    let mut got = vec![0f32; NUM_CLASSES];
    let batches = 12usize;
    for _ in 0..batches {
        s.infer_batch_into(&x, 1, &mut got).expect("infer");
    }
    let (checked, total) = s.scrub_progress();
    assert!(total > budget, "test needs a budget below the stripe space");
    assert_eq!(checked, (batches * budget) as u64, "budget accounting drifted");
    let r = s.reliability_stats();
    assert!(
        r.corrupt_bits_found <= r.upset_bits,
        "scrub found more corruption than the upset process landed: {r:?}"
    );
    // one full sweep repairs whatever is still pending; the next finds
    // nothing new (idempotence over the repaired state)
    let after_full = s.scrub_fabric();
    let again = s.scrub_fabric();
    assert_eq!(
        after_full.faults_detected, again.faults_detected,
        "second full scrub found new damage on a just-scrubbed fabric"
    );
    assert_eq!(
        again.faults_repaired + again.zeroed_rows,
        again.quarantined_rows,
        "quarantine bookkeeping split drifted: {again:?}"
    );
}
