//! Integration: multi-macro grid sharding semantics.
//!
//! The contract under test (DESIGN.md §Multi-macro scale-out): a
//! sharded conv on *any* grid shape is **byte-identical** to the
//! single-macro plan — pointwise/standard convs shard by output-channel
//! (FCC pair) range, depthwise convs shard by output-row band with
//! redundant halo compute — and the shard slices are provably disjoint
//! and covering.  Everything here runs on the hermetic seeded fabric;
//! no artifacts, no environment knobs (grid shapes are explicit, so the
//! parallel test harness never races on `DDC_GRID`).

use ddc_pim::arch::grid::{GridShape, MacroGrid};
use ddc_pim::arch::pim_core::MacroGeometry;
use ddc_pim::fcc::{fcc_transform, FilterBank};
use ddc_pim::mapping::exec::{ExecPool, PlannedConv, PlannedDwConv};
use ddc_pim::mapping::{ShardedConv, ShardedDwConv};
use ddc_pim::runtime::{BackendKind, BackendSpec, FabricChoice, IMG_ELEMS, NUM_CLASSES};
use ddc_pim::util::rng::Rng;

/// Every grid shape the acceptance criterion pins, including the
/// degenerate single tile and a tile count exceeding the FCC pair
/// count (empty shards must be dropped, not executed).
const SHAPES: [(usize, usize); 4] = [(1, 1), (1, 2), (2, 2), (2, 4)];

fn grid(rows: usize, cols: usize) -> MacroGrid {
    MacroGrid::new(GridShape::new(rows, cols), MacroGeometry::paper())
}

fn filters(rng: &mut Rng, n: usize, l: usize) -> Vec<i32> {
    (0..n * l).map(|_| rng.int8() as i32).collect()
}

#[test]
fn std_shard_channel_ranges_are_disjoint_and_covering() {
    let mut rng = Rng::new(21);
    let (h, w, c, k, n) = (6, 6, 8, 3, 8);
    let bank = FilterBank::new(filters(&mut rng, n, k * k * c), n, k * k * c);
    let fcc = fcc_transform(&bank);
    for (r, cl) in SHAPES {
        let plan = ShardedConv::std_fcc(&grid(r, cl), h, w, c, &fcc, k, 1, None);
        let ranges = plan.channel_ranges();
        assert_eq!(ranges.len(), plan.shard_count());
        assert!(!ranges.is_empty(), "{r}x{cl}: no shards");
        // tile order, contiguous, non-empty: strictly ascending ranges
        // that tile 0..out_channels exactly — disjoint AND covering
        let mut next = 0;
        for range in &ranges {
            assert_eq!(range.start, next, "{r}x{cl}: gap or overlap at {range:?}");
            assert!(range.end > range.start, "{r}x{cl}: empty shard kept");
            // FCC pair sharding: every boundary is a stored-pair edge
            assert_eq!(range.start % 2, 0, "{r}x{cl}: shard splits a pair");
            next = range.end;
        }
        assert_eq!(next, plan.out_channels(), "{r}x{cl}: channels uncovered");
        // 2x4 = 8 tiles but only 4 stored pairs: empties were dropped
        assert!(plan.shard_count() <= n / 2);
    }
}

#[test]
fn dw_shard_row_ranges_are_disjoint_and_covering() {
    let mut rng = Rng::new(22);
    let (h, w, c, k) = (9, 9, 6, 3);
    let bank = FilterBank::new(filters(&mut rng, c, k * k), c, k * k);
    let fcc = fcc_transform(&bank);
    for (r, cl) in SHAPES {
        let plan = ShardedDwConv::fcc(&grid(r, cl), h, w, c, &fcc, k, 1, true);
        let (oh, _) = plan.out_dims();
        let ranges = plan.row_ranges();
        assert_eq!(ranges.len(), plan.shard_count());
        let mut next = 0;
        for range in &ranges {
            assert_eq!(range.start, next, "{r}x{cl}: gap or overlap at {range:?}");
            assert!(range.end > range.start, "{r}x{cl}: empty row band kept");
            next = range.end;
        }
        assert_eq!(next, oh, "{r}x{cl}: output rows uncovered");
    }
}

#[test]
fn std_fcc_grid_matches_single_macro_at_every_shape_and_pool_width() {
    let mut rng = Rng::new(23);
    let (h, w, c, k, n, batch) = (6, 6, 8, 3, 8, 2);
    let bank = FilterBank::new(filters(&mut rng, n, k * k * c), n, k * k * c);
    let fcc = fcc_transform(&bank);
    let input: Vec<i32> = (0..batch * h * w * c).map(|_| rng.int8() as i32).collect();
    // the ground truth: the ordinary single-macro plan
    let single = PlannedConv::std_fcc(h, w, c, &fcc, k, 1);
    let mut pool = ExecPool::new(1);
    let mut want = vec![0i64; batch * single.out_len()];
    single.execute_batch_par(&input, batch, &mut pool, &mut want);
    for (r, cl) in SHAPES {
        let plan = ShardedConv::std_fcc(&grid(r, cl), h, w, c, &fcc, k, 1, None);
        assert_eq!(plan.out_len(), single.out_len());
        for threads in [1usize, 4] {
            let mut pool = ExecPool::new(threads);
            let mut scratch = Vec::new();
            let mut got = vec![0i64; batch * plan.out_len()];
            plan.execute_batch_par(&input, batch, &mut pool, &mut scratch, &mut got);
            assert_eq!(got, want, "{r}x{cl} grid diverged at {threads} threads");
        }
    }
}

#[test]
fn std_regular_grid_matches_single_macro_including_stride_2() {
    let mut rng = Rng::new(24);
    let (h, w, c, k, n, stride, batch) = (7, 7, 4, 3, 6, 2, 2);
    let weights = filters(&mut rng, n, k * k * c);
    let input: Vec<i32> = (0..batch * h * w * c).map(|_| rng.int8() as i32).collect();
    let single = PlannedConv::std_regular(h, w, c, &weights, n, k, stride);
    let mut pool = ExecPool::new(1);
    let mut want = vec![0i64; batch * single.out_len()];
    single.execute_batch_par(&input, batch, &mut pool, &mut want);
    for (r, cl) in SHAPES {
        let plan = ShardedConv::std_regular(&grid(r, cl), h, w, c, &weights, n, k, stride, None);
        for threads in [1usize, 4] {
            let mut pool = ExecPool::new(threads);
            let mut scratch = Vec::new();
            let mut got = vec![0i64; batch * plan.out_len()];
            plan.execute_batch_par(&input, batch, &mut pool, &mut scratch, &mut got);
            assert_eq!(got, want, "{r}x{cl} regular grid diverged at {threads} threads");
        }
    }
}

#[test]
fn dw_fcc_grid_matches_single_macro_at_every_shape_and_pool_width() {
    let mut rng = Rng::new(25);
    let (h, w, c, k) = (9, 9, 6, 3);
    let bank = FilterBank::new(filters(&mut rng, c, k * k), c, k * k);
    let fcc = fcc_transform(&bank);
    let input: Vec<i32> = (0..h * w * c).map(|_| rng.int8() as i32).collect();
    let single = PlannedDwConv::fcc(h, w, c, &fcc, k, 1, true);
    let mut pool = ExecPool::new(1);
    let mut want = vec![0i64; single.out_len()];
    single.execute_par(&input, &mut pool, &mut want);
    for (r, cl) in SHAPES {
        // spatial halo sharding: seam rows must agree exactly with the
        // unsharded SAME-padded window math
        let plan = ShardedDwConv::fcc(&grid(r, cl), h, w, c, &fcc, k, 1, true);
        for threads in [1usize, 4] {
            let mut pool = ExecPool::new(threads);
            let mut scratch = Vec::new();
            let mut got = vec![0i64; plan.out_len()];
            plan.execute_par(&input, &mut pool, &mut scratch, &mut got);
            assert_eq!(got, want, "{r}x{cl} dw grid diverged at {threads} threads");
        }
    }
}

#[test]
fn dw_regular_grid_matches_single_macro_at_stride_2() {
    let mut rng = Rng::new(26);
    let (h, w, c, k, stride) = (10, 8, 5, 3, 2);
    let weights = filters(&mut rng, c, k * k);
    let input: Vec<i32> = (0..h * w * c).map(|_| rng.int8() as i32).collect();
    let single = PlannedDwConv::regular(h, w, c, &weights, k, stride);
    let mut pool = ExecPool::new(1);
    let mut want = vec![0i64; single.out_len()];
    single.execute_par(&input, &mut pool, &mut want);
    for (r, cl) in SHAPES {
        let plan = ShardedDwConv::regular(&grid(r, cl), h, w, c, &weights, k, stride);
        for threads in [1usize, 4] {
            let mut pool = ExecPool::new(threads);
            let mut scratch = Vec::new();
            let mut got = vec![0i64; plan.out_len()];
            plan.execute_par(&input, &mut pool, &mut scratch, &mut got);
            assert_eq!(got, want, "{r}x{cl} dw-regular grid diverged at {threads} threads");
        }
    }
}

#[test]
fn faulted_grid_stays_byte_identical_after_scrub_repair() {
    // shard-salted fault patterns differ per tile, but the scrub's
    // detect+repair must restore every shard to the pristine logits —
    // the same end state the single-macro faulted plan reaches
    use ddc_pim::arch::fault::FaultConfig;
    let mut rng = Rng::new(27);
    let (h, w, c, k, n) = (6, 6, 8, 3, 8);
    let bank = FilterBank::new(filters(&mut rng, n, k * k * c), n, k * k * c);
    let fcc = fcc_transform(&bank);
    let input: Vec<i32> = (0..h * w * c).map(|_| rng.int8() as i32).collect();
    let pristine = ShardedConv::std_fcc(&grid(2, 2), h, w, c, &fcc, k, 1, None);
    let mut pool = ExecPool::new(2);
    let mut scratch = Vec::new();
    let mut want = vec![0i64; pristine.out_len()];
    pristine.execute_par(&input, &mut pool, &mut scratch, &mut want);
    let faults = FaultConfig::new(0xDDC7, 0.002);
    let mut faulted = ShardedConv::std_fcc(&grid(2, 2), h, w, c, &fcc, k, 1, Some(&faults));
    let tally = faulted.fault_tally();
    assert!(tally.injected_bits > 0, "BER 2000 ppm manifested no faults");
    let report = faulted.scrub();
    assert!(report.checked_words > 0);
    let mut got = vec![0i64; faulted.out_len()];
    faulted.execute_par(&input, &mut pool, &mut scratch, &mut got);
    assert_eq!(got, want, "scrubbed 2x2 grid diverged from pristine");
}

#[test]
fn session_logits_are_grid_invariant() {
    // end to end through the reference runtime: bit-sliced sessions on
    // 1x1, 1x2 and 2x2 grids and the dense kernel all agree exactly
    let mut rng = Rng::new(28);
    let img: Vec<f32> = (0..IMG_ELEMS).map(|_| rng.normal() as f32).collect();
    let infer = |spec: BackendSpec| -> Vec<f32> {
        let mut out = vec![0f32; NUM_CLASSES];
        spec.create("/nonexistent")
            .expect("backend")
            .prepare()
            .expect("session")
            .infer_batch_into(&img, 1, &mut out)
            .expect("inference");
        out
    };
    let dense = infer(BackendSpec {
        kind: BackendKind::Reference,
        ..Default::default()
    });
    for (r, cl) in [(1, 1), (1, 2), (2, 2)] {
        let got = infer(BackendSpec {
            kind: BackendKind::Reference,
            fabric: FabricChoice::BitSliced,
            threads: 2,
            grid: GridShape::new(r, cl),
            ..Default::default()
        });
        assert_eq!(got, dense, "{r}x{cl} session logits diverged");
    }
}
