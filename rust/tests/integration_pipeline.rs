//! Integration: the full mapper -> functional-fabric -> merge pipeline
//! against direct-conv oracles, and the timing engine's cross-module
//! consistency on real networks.

use ddc_pim::config::{ArchConfig, SimConfig};
use ddc_pim::fcc::{fcc_transform, FilterBank};
use ddc_pim::isa::{assemble, Instr, Op};
use ddc_pim::mapping::exec::{exec_dw_fcc, exec_std_fcc, exec_std_regular};
use ddc_pim::mapping::im2col::{direct_conv, direct_dwconv};
use ddc_pim::mapping::{plan_network, PlanKind};
use ddc_pim::model::zoo;
use ddc_pim::sim::simulate_network;
use ddc_pim::util::prop::forall_explain;
use ddc_pim::util::rng::Rng;

/// Property: for ANY random layer shape + filters, the DDC functional
/// path (half the weights stored, Q-bar recovery, ARU) equals direct
/// convolution with the full biased-comp bank.
#[test]
fn property_std_fcc_equals_direct_conv() {
    forall_explain(
        1234,
        12,
        |r: &mut Rng| {
            let h = 2 + r.below(4) as usize;
            let c = 1 + r.below(6) as usize;
            let n = 2 * (1 + r.below(4) as usize);
            let k = [1usize, 3][r.below(2) as usize];
            let stride = 1 + r.below(2) as usize;
            let input: Vec<i32> = (0..h * h * c).map(|_| r.int8() as i32).collect();
            let bank: Vec<i32> = (0..n * k * k * c).map(|_| r.int8() as i32).collect();
            (h, c, n, k, stride, input, bank)
        },
        |(h, c, n, k, stride, input, bank)| {
            let l = k * k * c;
            let fcc = fcc_transform(&FilterBank::new(bank.clone(), *n, l));
            let got = exec_std_fcc(input, *h, *h, *c, &fcc, *k, *stride);
            let mut bc = vec![0i32; n * l];
            for p in 0..n / 2 {
                for i in 0..l {
                    bc[(2 * p) * l + i] = fcc.comp.filter(2 * p)[i] + fcc.means[p];
                    bc[(2 * p + 1) * l + i] = fcc.comp.filter(2 * p + 1)[i] + fcc.means[p];
                }
            }
            let want = direct_conv(input, *h, *h, *c, &bc, *n, *k, *stride);
            if got == want {
                Ok(())
            } else {
                Err("DDC functional path != direct conv".into())
            }
        },
    );
}

#[test]
fn property_dw_fcc_equals_direct_conv() {
    forall_explain(
        987,
        10,
        |r: &mut Rng| {
            let h = 3 + r.below(3) as usize;
            let c = 2 * (1 + r.below(6) as usize);
            let reconfig = r.below(2) == 1;
            let input: Vec<i32> = (0..h * h * c).map(|_| r.int8() as i32).collect();
            let bank: Vec<i32> = (0..c * 9).map(|_| r.int8() as i32).collect();
            (h, c, reconfig, input, bank)
        },
        |(h, c, reconfig, input, bank)| {
            let fcc = fcc_transform(&FilterBank::new(bank.clone(), *c, 9));
            let got = exec_dw_fcc(input, *h, *h, *c, &fcc, 3, 1, *reconfig);
            let mut bc = vec![0i32; c * 9];
            for p in 0..c / 2 {
                for i in 0..9 {
                    bc[(2 * p) * 9 + i] = fcc.comp.filter(2 * p)[i] + fcc.means[p];
                    bc[(2 * p + 1) * 9 + i] = fcc.comp.filter(2 * p + 1)[i] + fcc.means[p];
                }
            }
            let want = direct_dwconv(input, *h, *h, *c, &bc, 3, 1);
            if got == want {
                Ok(())
            } else {
                Err(format!("dw mismatch (reconfig={reconfig})"))
            }
        },
    );
}

/// The FCC and non-FCC functional paths agree when fed equivalent banks:
/// regular execution of the recomposed biased-comp filters == FCC
/// execution of the stored halves.
#[test]
fn fcc_and_regular_paths_agree() {
    let mut rng = Rng::new(55);
    let (h, c, n, k) = (4usize, 3usize, 6usize, 3usize);
    let l = k * k * c;
    let input: Vec<i32> = (0..h * h * c).map(|_| rng.int8() as i32).collect();
    let bank = FilterBank::new((0..n * l).map(|_| rng.int8() as i32).collect(), n, l);
    let fcc = fcc_transform(&bank);
    let mut bc = vec![0i32; n * l];
    for p in 0..n / 2 {
        for i in 0..l {
            bc[(2 * p) * l + i] = fcc.comp.filter(2 * p)[i] + fcc.means[p];
            bc[(2 * p + 1) * l + i] = fcc.comp.filter(2 * p + 1)[i] + fcc.means[p];
        }
    }
    let via_fcc = exec_std_fcc(&input, h, h, c, &fcc, k, 1);
    let via_regular = exec_std_regular(&input, h, h, c, &bc, n, k, 1);
    assert_eq!(via_fcc, via_regular);
}

/// Timing engine consistency across the whole zoo: DDC never loses to
/// the baseline, MAC counts are config-invariant, ISA streams decode.
#[test]
fn zoo_wide_timing_invariants() {
    for name in zoo::ALL_MODELS {
        let net = zoo::by_name(name).unwrap();
        let base = simulate_network(&net, &ArchConfig::baseline(), &SimConfig::baseline());
        let ddc = simulate_network(&net, &ArchConfig::ddc_pim(), &SimConfig::ddc_full());
        assert!(
            ddc.total_cycles <= base.total_cycles,
            "{name}: DDC slower than baseline"
        );
        assert_eq!(ddc.total_macs, base.total_macs, "{name}: MACs changed");
        assert!(ddc.total_dram_bytes <= base.total_dram_bytes, "{name}");
        let plans = plan_network(&net, &ArchConfig::ddc_pim(), &SimConfig::ddc_full());
        for word in assemble(&plans) {
            assert!(Instr::decode(word).is_some(), "{name}: bad ISA word");
        }
    }
}

/// dw plans in the DDC config must actually use the accelerated kinds.
#[test]
fn mobilenet_dw_layers_accelerated() {
    let net = zoo::mobilenet_v2();
    let plans = plan_network(&net, &ArchConfig::ddc_pim(), &SimConfig::ddc_full());
    let dw_kinds: Vec<PlanKind> = plans
        .iter()
        .filter(|p| {
            matches!(
                p.kind,
                PlanKind::DwRegular | PlanKind::DwDbis | PlanKind::DwReconfig
            )
        })
        .map(|p| p.kind)
        .collect();
    assert!(!dw_kinds.is_empty());
    assert!(
        dw_kinds.iter().all(|k| *k == PlanKind::DwReconfig),
        "3x3 dw should all use the reconfig mapping: {dw_kinds:?}"
    );
}

/// ISA round-trip preserves the full stream.
#[test]
fn isa_stream_roundtrip() {
    let net = zoo::efficientnet_b0();
    let plans = plan_network(&net, &ArchConfig::ddc_pim(), &SimConfig::ddc_full());
    let words = assemble(&plans);
    let decoded: Vec<Instr> = words.iter().map(|&w| Instr::decode(w).unwrap()).collect();
    assert_eq!(decoded.last().unwrap().op, Op::Halt);
    let reencoded: Vec<u64> = decoded.iter().map(Instr::encode).collect();
    assert_eq!(words, reencoded);
}
