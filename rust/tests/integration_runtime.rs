//! Integration: the AOT bridge — load every HLO-text artifact through
//! PJRT and replay the python-side goldens bit-exactly.
//!
//! Requires `make artifacts` (skips cleanly when absent so `cargo test`
//! works in a fresh checkout).

use ddc_pim::runtime::{artifacts, Runtime};

fn artifact_dir() -> Option<String> {
    for dir in ["artifacts", "../artifacts"] {
        if std::path::Path::new(dir).join("goldens.json").exists() {
            return Some(dir.to_string());
        }
    }
    eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
    None
}

#[test]
fn fcc_mvm_kernel_golden_exact() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::cpu(&dir).expect("PJRT client");
    let goldens = artifacts::load_goldens(&dir).expect("goldens");
    let (_, g) = goldens
        .iter()
        .find(|(n, _)| n == "fcc_mvm")
        .expect("fcc_mvm golden");
    let exe = rt.load("fcc_mvm").expect("compile fcc_mvm");
    let out = exe
        .run_i32(&[
            (&g.x_i32(), &g.x_shape),
            (&g.w_i32(), &g.w_shape),
            (&g.m_i32(), &g.m_shape),
        ])
        .expect("execute");
    assert_eq!(out, g.out_i32(), "pallas FCC kernel output mismatch");
}

#[test]
fn pim_mac_kernel_golden_exact() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::cpu(&dir).expect("PJRT client");
    let goldens = artifacts::load_goldens(&dir).expect("goldens");
    let (_, g) = goldens
        .iter()
        .find(|(n, _)| n == "pim_mac")
        .expect("pim_mac golden");
    let exe = rt.load("pim_mac").expect("compile pim_mac");
    let out = exe
        .run_i32(&[(&g.x_i32(), &g.x_shape), (&g.w_i32(), &g.w_shape)])
        .expect("execute");
    assert_eq!(out, g.out_i32(), "bit-serial pim_mac kernel mismatch");
}

#[test]
fn model_b1_golden_close() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::cpu(&dir).expect("PJRT client");
    let goldens = artifacts::load_goldens(&dir).expect("goldens");
    let (_, g) = goldens
        .iter()
        .find(|(n, _)| n == "model_b1")
        .expect("model golden");
    let weights = artifacts::load_model_weights(&dir).expect("weights sidecar");
    let out = rt
        .run_model("model_b1", &g.x_f32(), &g.x_shape, &weights)
        .expect("execute");
    let want = g.out_f32();
    assert_eq!(out.len(), want.len());
    let max_err = out
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 1e-3, "model max |err| = {max_err}");
}

#[test]
fn fcc_mvm_matches_rust_fcc_semantics() {
    // cross-layer check: the pallas kernel's FCC recovery must agree
    // with the rust-side definition (ref oracle reimplemented here)
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::cpu(&dir).expect("PJRT client");
    let goldens = artifacts::load_goldens(&dir).expect("goldens");
    let (_, g) = goldens.iter().find(|(n, _)| n == "fcc_mvm").unwrap();
    let (b, l) = (g.x_shape[0] as usize, g.x_shape[1] as usize);
    let half = g.w_shape[1] as usize;
    let x = g.x_i32();
    let w = g.w_i32(); // [L, half] column-major filters
    let m = g.m_i32();
    let mut want = vec![0i32; b * 2 * half];
    for bi in 0..b {
        let si: i64 = x[bi * l..(bi + 1) * l].iter().map(|&v| v as i64).sum();
        for p in 0..half {
            let mut psum = 0i64;
            for li in 0..l {
                psum += x[bi * l + li] as i64 * w[li * half + p] as i64;
            }
            want[bi * 2 * half + 2 * p] = (psum + si * m[p] as i64) as i32;
            want[bi * 2 * half + 2 * p + 1] = (si * (m[p] as i64 - 1) - psum) as i32;
        }
    }
    let exe = rt.load("fcc_mvm").unwrap();
    let out = exe
        .run_i32(&[
            (&g.x_i32(), &g.x_shape),
            (&g.w_i32(), &g.w_shape),
            (&g.m_i32(), &g.m_shape),
        ])
        .unwrap();
    assert_eq!(out, want, "kernel semantics drifted from Eq. 7");
}

#[test]
fn model_batch8_runs() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::cpu(&dir).expect("PJRT client");
    let weights = artifacts::load_model_weights(&dir).expect("weights sidecar");
    let input = vec![0.5f32; 8 * 32 * 32 * 3];
    let out = rt
        .run_model("model_b8", &input, &[8, 32, 32, 3], &weights)
        .expect("execute");
    assert_eq!(out.len(), 8 * 10);
    // identical rows in, identical logits out
    for i in 1..8 {
        assert_eq!(out[..10], out[i * 10..(i + 1) * 10]);
    }
}
