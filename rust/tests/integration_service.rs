//! Integration: the inference service end-to-end (request -> batcher ->
//! PJRT -> response).  Requires artifacts; skips cleanly otherwise.

use ddc_pim::coordinator::{BatchPolicy, InferenceService};
use ddc_pim::util::rng::Rng;
use std::time::Duration;

fn artifact_dir() -> Option<String> {
    for dir in ["artifacts", "../artifacts"] {
        if std::path::Path::new(dir).join("model_b1.hlo.txt").exists() {
            return Some(dir.to_string());
        }
    }
    eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
    None
}

fn image(rng: &mut Rng) -> Vec<f32> {
    (0..32 * 32 * 3).map(|_| rng.normal() as f32).collect()
}

#[test]
fn single_request_roundtrip() {
    let Some(dir) = artifact_dir() else { return };
    let svc = InferenceService::start(dir, BatchPolicy::default());
    let mut rng = Rng::new(1);
    let r = svc.infer(image(&mut rng)).expect("inference");
    assert_eq!(r.logits.len(), 10);
    assert!(r.argmax < 10);
    assert!(r.simulated_ms > 0.0);
}

#[test]
fn batched_requests_all_answered() {
    let Some(dir) = artifact_dir() else { return };
    let svc = InferenceService::start(
        dir,
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
        },
    );
    let mut rng = Rng::new(2);
    let rxs: Vec<_> = (0..24).map(|_| svc.submit(image(&mut rng))).collect();
    let mut batched = 0;
    for rx in rxs {
        let r = rx.recv().expect("channel").expect("inference");
        assert_eq!(r.logits.len(), 10);
        if r.batch_size > 1 {
            batched += 1;
        }
    }
    assert!(batched > 0, "no request ever rode a batch");
    let stats = svc.stats().expect("stats");
    assert_eq!(stats.requests, 24);
    assert!(stats.batches <= 24);
}

#[test]
fn deterministic_logits_for_same_input() {
    let Some(dir) = artifact_dir() else { return };
    let svc = InferenceService::start(dir, BatchPolicy::default());
    let mut rng = Rng::new(3);
    let img = image(&mut rng);
    let a = svc.infer(img.clone()).expect("a");
    let b = svc.infer(img).expect("b");
    assert_eq!(a.logits, b.logits);
}

#[test]
fn service_survives_mixed_good_and_bad_requests() {
    let Some(dir) = artifact_dir() else { return };
    let svc = InferenceService::start(dir, BatchPolicy::default());
    let mut rng = Rng::new(4);
    assert!(svc.infer(vec![0.0; 7]).is_err()); // malformed
    let r = svc.infer(image(&mut rng)); // still serving
    assert!(r.is_ok(), "service died after bad request: {r:?}");
}
