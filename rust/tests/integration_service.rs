//! Integration: the inference service end-to-end (request -> batcher ->
//! backend -> response).
//!
//! Runs unconditionally: with no artifacts present the backend factory
//! falls back to the hermetic reference backend, so CI exercises the
//! full serving path on every checkout.  (With `--features pjrt` + a
//! real xla crate + `make artifacts`, the same tests cover the PJRT
//! path through backend auto-selection.)

use ddc_pim::coordinator::{
    BatchPolicy, InferenceService, ServiceConfig, ServiceError, IMG_ELEMS, NUM_CLASSES,
};
use ddc_pim::runtime::{BackendKind, BackendSpec};
use ddc_pim::util::rng::Rng;
use std::time::Duration;

/// Tests run with CWD = the package root (`rust/`), but `make
/// artifacts` writes to the repo root — probe both so a PJRT-enabled
/// build with real artifacts actually auto-selects them.
fn artifact_dir() -> String {
    for dir in ["artifacts", "../artifacts"] {
        if std::path::Path::new(dir).join("model_b1.hlo.txt").exists() {
            return dir.to_string();
        }
    }
    "artifacts".to_string()
}

fn service() -> InferenceService {
    InferenceService::start(artifact_dir(), BatchPolicy::default())
}

fn image(rng: &mut Rng) -> Vec<f32> {
    (0..IMG_ELEMS).map(|_| rng.normal() as f32).collect()
}

#[test]
fn single_request_roundtrip() {
    let svc = service();
    let mut rng = Rng::new(1);
    let r = svc.infer(image(&mut rng)).expect("inference");
    assert_eq!(r.logits.len(), NUM_CLASSES);
    assert!(r.argmax < NUM_CLASSES);
    assert!(r.simulated_ms > 0.0);
    assert!(!r.backend.is_empty());
}

#[test]
fn batched_requests_all_answered() {
    let svc = InferenceService::start(
        artifact_dir(),
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
        },
    );
    let mut rng = Rng::new(2);
    let rxs: Vec<_> = (0..24).map(|_| svc.submit(image(&mut rng))).collect();
    let mut batched = 0;
    for rx in rxs {
        let r = rx.recv().expect("channel").expect("inference");
        assert_eq!(r.logits.len(), NUM_CLASSES);
        if r.batch_size > 1 {
            batched += 1;
        }
    }
    assert!(batched > 0, "no request ever rode a batch");
    let stats = svc.stats().expect("stats");
    assert_eq!(stats.requests, 24);
    assert!(stats.batches <= 24);
    assert!(stats.p50() <= stats.p95());
    assert!(stats.p95() <= stats.p99());
    // an unbounded service admits everything and sheds nothing
    assert_eq!(stats.admission.admitted, 24);
    assert_eq!(stats.admission.rejected, 0);
    assert!(stats.admission.peak_queue_depth >= 1);
}

#[test]
fn worker_pool_drains_a_burst_with_correct_logits() {
    // the same request set through 1 worker and through 3: every
    // response byte-identical regardless of which session served it
    let single = service();
    let cluster = InferenceService::start_cluster(
        BackendSpec::new(BackendKind::Auto),
        artifact_dir(),
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
        },
        ServiceConfig {
            workers: 3,
            max_queue_depth: 0,
        },
    );
    assert_eq!(cluster.worker_count(), 3);
    let mut rng = Rng::new(10);
    let imgs: Vec<Vec<f32>> = (0..12).map(|_| image(&mut rng)).collect();
    let want: Vec<_> = imgs
        .iter()
        .map(|img| single.infer(img.clone()).expect("single").logits)
        .collect();
    let rxs: Vec<_> = imgs
        .iter()
        .map(|img| cluster.submit(img.clone()))
        .collect();
    for (rx, want) in rxs.into_iter().zip(&want) {
        let got = rx.recv().expect("channel").expect("cluster inference");
        assert_eq!(&got.logits, want, "a worker session drifted");
    }
    let stats = cluster.stats().expect("stats");
    assert_eq!(stats.requests, 12);
    assert_eq!(stats.admission.admitted, 12);
    assert_eq!(stats.admission.workers, 3);
}

#[test]
fn bounded_queue_sheds_excess_load_with_typed_rejections() {
    // an hour-long batch window wedges admitted requests in the
    // batcher, so the shed point is exact: depth 2 admits two, the
    // third bounces synchronously
    let svc = InferenceService::start_cluster(
        BackendSpec::new(BackendKind::Auto),
        artifact_dir(),
        BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_secs(3600),
        },
        ServiceConfig {
            workers: 1,
            max_queue_depth: 2,
        },
    );
    let mut rng = Rng::new(11);
    let a = svc.submit(image(&mut rng));
    let b = svc.submit(image(&mut rng));
    let shed = svc.submit(image(&mut rng)).recv().expect("channel");
    assert!(
        matches!(shed, Err(ServiceError::Overloaded)),
        "expected a typed Overloaded rejection, got {shed:?}"
    );
    let stats = svc.stats().expect("stats");
    assert_eq!(stats.admission.admitted, 2);
    assert_eq!(stats.admission.rejected, 1);
    assert_eq!(stats.admission.max_queue_depth, 2);
    assert_eq!(stats.admission.peak_queue_depth, 2);
    // shutdown drains the admitted requests — shed load never costs
    // the queued requests their answers
    drop(svc);
    assert!(a.recv().expect("channel").is_ok(), "queued request dropped");
    assert!(b.recv().expect("channel").is_ok(), "queued request dropped");
}

#[test]
fn deterministic_logits_for_same_input() {
    let svc = service();
    let mut rng = Rng::new(3);
    let img = image(&mut rng);
    let a = svc.infer(img.clone()).expect("a");
    let b = svc.infer(img).expect("b");
    assert_eq!(a.logits, b.logits);
}

#[test]
fn service_survives_mixed_good_and_bad_requests() {
    let svc = service();
    let mut rng = Rng::new(4);
    assert!(svc.infer(vec![0.0; 7]).is_err()); // malformed
    let r = svc.infer(image(&mut rng)); // still serving
    assert!(r.is_ok(), "service died after bad request: {r:?}");
}

#[test]
fn bad_request_does_not_poison_its_batch() {
    // malformed inputs are rejected at submit time, so valid requests
    // sharing the same batching window still succeed
    let svc = InferenceService::start(
        artifact_dir(),
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(20),
        },
    );
    let mut rng = Rng::new(6);
    let good1 = svc.submit(image(&mut rng));
    let bad = svc.submit(vec![0.0; 5]);
    let good2 = svc.submit(image(&mut rng));
    assert!(bad.recv().expect("channel").is_err());
    assert!(good1.recv().expect("channel").is_ok(), "good request poisoned");
    assert!(good2.recv().expect("channel").is_ok(), "good request poisoned");
}

#[test]
fn service_recovers_from_worker_panic_mid_traffic() {
    // a panicking batch execution must be caught, the session rebuilt,
    // and the same requests served by the retry — degraded (one rebuild
    // booked) but correct, with no process abort and no hung client
    let svc = service();
    let mut rng = Rng::new(8);
    let img = image(&mut rng);
    let want = svc.infer(img.clone()).expect("baseline").logits;
    svc.debug_panic_next_batch();
    let got = svc.infer(img).expect("served across the panic");
    assert_eq!(got.logits, want, "rebuilt session disagreed with the original");
    assert_eq!(svc.stats().expect("stats").reliability.worker_rebuilds, 1);
    for _ in 0..4 {
        assert!(svc.infer(image(&mut rng)).is_ok(), "service degraded after rebuild");
    }
}

#[test]
fn client_timeout_is_typed_and_counted() {
    use ddc_pim::coordinator::ServiceError;
    let svc = service();
    let mut rng = Rng::new(9);
    svc.infer(image(&mut rng)).expect("warm-up");
    svc.debug_hang_next_batch(Duration::from_millis(300));
    let err = svc
        .infer_timeout(image(&mut rng), Duration::from_millis(20))
        .expect_err("a stalled worker must surface as a timeout");
    assert_eq!(err, ServiceError::Timeout);
    assert_eq!(svc.stats().expect("stats").reliability.timed_out_requests, 1);
    // the worker was stalled, not dead: traffic resumes
    assert!(svc.infer(image(&mut rng)).is_ok());
}

#[test]
fn distinct_inputs_get_distinct_logits() {
    let svc = service();
    let mut rng = Rng::new(5);
    let a = svc.infer(image(&mut rng)).expect("a");
    let b = svc.infer(image(&mut rng)).expect("b");
    assert_ne!(a.logits, b.logits, "logits insensitive to input");
}
