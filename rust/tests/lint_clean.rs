//! Tier-1 gate for `ddc-lint` itself: the real tree must lint clean,
//! every fixture must trip exactly its rule, and the interleaving
//! checker must clear ≥1000 seeded schedules of both protocols while
//! still catching the planted-bug variants.

use std::path::PathBuf;

use ddc_pim::util::lint::{self, manifest, shuttle, Config};

fn repo_config() -> Config {
    let manifest_path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../lint-hotpaths.toml");
    let text = std::fs::read_to_string(&manifest_path)
        .unwrap_or_else(|e| panic!("read {}: {e}", manifest_path.display()));
    let man = manifest::parse(&text).expect("lint-hotpaths.toml parses");
    Config::from_manifest(&man)
}

#[test]
fn repo_tree_lints_clean() {
    let src = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
    let findings = lint::lint_tree(&src, &repo_config());
    assert!(
        findings.is_empty(),
        "ddc-lint findings in the tree:\n{}",
        findings
            .iter()
            .map(|f| format!("  {f}"))
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn manifest_names_real_functions() {
    // a typoed manifest entry would silently scope a rule to nothing;
    // require every named hot/no-panic function to exist in its file
    let src_root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
    let cfg = repo_config();
    for (section, table) in [("no_alloc", &cfg.no_alloc), ("no_panic", &cfg.no_panic)] {
        for (file, fns) in table {
            let text = std::fs::read_to_string(src_root.join(file))
                .unwrap_or_else(|e| panic!("[{section}] names missing file {file}: {e}"));
            for f in fns {
                if f == "*" {
                    continue;
                }
                assert!(
                    text.contains(&format!("fn {f}")),
                    "[{section}] {file}: no `fn {f}` in that file — stale manifest entry"
                );
            }
        }
    }
    for key in cfg.atomics.keys() {
        let (file, f) = key.split_once("::").expect("atomics key is file::fn");
        let text = std::fs::read_to_string(src_root.join(file))
            .unwrap_or_else(|e| panic!("[atomics] names missing file {file}: {e}"));
        assert!(
            text.contains(&format!("fn {f}")),
            "[atomics] {key}: no `fn {f}` in {file} — stale manifest entry"
        );
    }
}

#[test]
fn fixtures_each_trip_exactly_their_rule() {
    let fixtures = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/lint_fixtures");
    lint::self_check(&fixtures, &repo_config()).expect("fixture self-check");
}

#[test]
fn fixture_expectations_cover_every_fixture_file() {
    // a fixture added without an expectation entry would never run
    let fixtures = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/lint_fixtures");
    let mut on_disk: Vec<String> = std::fs::read_dir(&fixtures)
        .expect("fixtures dir")
        .flatten()
        .filter_map(|e| {
            let p = e.path();
            (p.extension()? == "rs").then(|| p.file_stem().unwrap().to_string_lossy().into_owned())
        })
        .collect();
    on_disk.sort();
    let mut expected: Vec<String> = lint::FIXTURE_EXPECTATIONS
        .iter()
        .map(|(stem, _, _)| stem.to_string())
        .collect();
    expected.sort();
    assert_eq!(on_disk, expected);
}

// trimmed under Miri: interpreted steps are ~1000x slower and the
// schedules are identical either way
const SHUTTLE_SEEDS: u64 = if cfg!(miri) { 32 } else { 1000 };

#[test]
fn shuttle_clears_both_protocols() {
    let steal = shuttle::check_steal_protocol(SHUTTLE_SEEDS, 4, 24);
    assert_eq!(steal.schedules, SHUTTLE_SEEDS);
    assert!(steal.ok(), "steal protocol violations: {:?}", steal.violations);
    let gate = shuttle::check_admission_gate(SHUTTLE_SEEDS, 6, 2);
    assert_eq!(gate.schedules, SHUTTLE_SEEDS);
    assert!(gate.ok(), "admission gate violations: {:?}", gate.violations);
}

#[test]
fn shuttle_catches_planted_bugs() {
    assert!(
        !shuttle::check_steal_protocol_buggy(SHUTTLE_SEEDS, 4, 12).ok(),
        "planted pop lost-update not found — the checker has no teeth"
    );
    assert!(
        !shuttle::check_admission_gate_buggy(SHUTTLE_SEEDS, 6, 2).ok(),
        "planted admission blind-store not found — the checker has no teeth"
    );
}
