//! ddc-lint fixture: violates `atomics` and nothing else.
//! Linted as `util/pool.rs`: the `[atomics]` protocol table says `pop`
//! uses Acquire/AcqRel, so the Relaxed load below is off-protocol.
//! Never compiled.

fn pop(range: &AtomicU64) -> u64 {
    range.load(Ordering::Relaxed)
}
