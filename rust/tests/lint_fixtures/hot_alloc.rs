//! ddc-lint fixture: violates `hot_alloc` and nothing else.
//! Linted as `mapping/exec.rs`, whose `[no_alloc]` manifest entry
//! names `execute` — so the allocation below is in scope.  Never
//! compiled.

pub fn execute(out: &mut [i32]) {
    // steady-state execute must reuse pre-sized buffers
    let scratch: Vec<i32> = Vec::new();
    let _ = (out, scratch);
}
