//! ddc-lint fixture: violates `no_panic` and nothing else.
//! Linted as `coordinator/service.rs` (whole file in the `[no_panic]`
//! manifest scope).  Never compiled.

pub fn shed_or_crash(slot: Option<u32>) -> u32 {
    // a serving path must degrade via typed errors, not abort
    slot.unwrap()
}
