//! ddc-lint fixture: violates `unsafe_module` and nothing else.
//! Linted as `model/rogue.rs` — a module with no business holding
//! `unsafe` (the SAFETY comment is present so only the module rule
//! fires).  Never compiled.

pub fn peek(p: *const u32) -> u32 {
    // SAFETY: caller guarantees p is valid (but this module may not
    // contain unsafe at all, documented or not)
    unsafe { *p }
}
