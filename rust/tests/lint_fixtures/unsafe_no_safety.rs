//! ddc-lint fixture: violates `unsafe_safety` and nothing else.
//! Linted as `mapping/exec.rs` (an allowlisted unsafe module), so the
//! only finding is the missing SAFETY comment.  Never compiled.

pub fn undocumented(p: *mut u32) {
    unsafe {
        *p = 7;
    }
}
