//! ddc-lint fixture: violates `waiver` and nothing else.
//! Linted as `coordinator/service.rs`.  A reasonless waiver is itself
//! a finding AND suppresses nothing — but here it waives a line with
//! no violation, so only the `waiver` finding fires.  Never compiled.

pub fn quiet() -> u32 {
    // ddc-lint: allow(no_panic)
    41 + 1
}
