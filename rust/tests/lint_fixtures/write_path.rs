//! ddc-lint fixture: violates `write_path` and nothing else.
//! Linted as `mapping/rogue.rs` (not on the arch write path) by the
//! self-check and `tests/lint_clean.rs`.  Never compiled — `tests/`
//! subdirectories are not cargo test targets.

pub fn sneak_a_weight(cmp: &mut Compartment) {
    // bypasses PimCore::write_weight: no complement coherence, no
    // sparsity summary update, no fault-intent ledger entry
    cmp.write_weight8(0, 3, 0x5a);
}
