//! Integration: parallel execution is byte-identical to serial (PR 4
//! acceptance criterion).
//!
//! `execute_par` / `execute_batch_par` shard `(pass, pixel-block)` work
//! units across an `ExecPool`; because every unit writes a disjoint
//! output slice and reads only shared staging, the result must be
//! byte-identical to the serial `execute` at *every* pool width — for
//! both conv executors, every mapping mode (Regular/Double computing ×
//! Combined/Split grouping), and the whole session stack.  This suite
//! also runs under `--features scalar-fabric` in CI, covering both
//! fabric implementations.

use ddc_pim::fcc::{fcc_transform, FilterBank};
use ddc_pim::mapping::exec::{ExecCtx, ExecPool, PlannedConv, PlannedDwConv};
use ddc_pim::runtime::{
    reference::{fcc_mvm_i32, fcc_mvm_into_par, mvm_i32, mvm_i32_into_par, ReferenceBackend},
    Backend, FabricChoice, Session, IMG_ELEMS, NUM_CLASSES,
};
use ddc_pim::util::rng::Rng;

const WIDTHS: [usize; 3] = [1, 2, 8];

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<i32> {
    (0..n).map(|_| rng.int8() as i32).collect()
}

/// Serial baseline for a std/pw plan.
fn serial(plan: &PlannedConv, input: &[i32]) -> Vec<i64> {
    let mut ctx = ExecCtx::new();
    let mut out = vec![0i64; plan.out_len()];
    plan.execute(input, &mut ctx, &mut out);
    out
}

#[test]
fn std_fcc_double_combined_pinned_across_widths() {
    // Double computing × Combined grouping, multi-pass + multi-block
    // (18x18 = 324 pixels > one 64-pixel block; 132 filters force a
    // second weight-reload pass)
    let mut rng = Rng::new(400);
    let (h, w, c, k, n) = (18, 18, 40, 1, 132);
    let input = rand_vec(&mut rng, h * w * c);
    let bank = FilterBank::new(rand_vec(&mut rng, n * c), n, c);
    let fcc = fcc_transform(&bank);
    let plan = PlannedConv::std_fcc(h, w, c, &fcc, k, 1);
    assert!(plan.load_passes() >= 2, "shape was meant to force a reload pass");
    let want = serial(&plan, &input);
    for width in WIDTHS {
        let mut pool = ExecPool::new(width);
        let mut got = vec![-7i64; plan.out_len()]; // dirty sentinel
        plan.execute_par(&input, &mut pool, &mut got);
        assert_eq!(got, want, "std_fcc diverged at width {width}");
    }
}

#[test]
fn std_regular_pinned_across_widths() {
    // Regular computing (PIM baseline): Q path only
    let mut rng = Rng::new(401);
    let (h, w, c, k, n) = (12, 12, 3, 3, 5);
    let input = rand_vec(&mut rng, h * w * c);
    let filters = rand_vec(&mut rng, n * k * k * c);
    let plan = PlannedConv::std_regular(h, w, c, &filters, n, k, 1);
    let want = serial(&plan, &input);
    for width in WIDTHS {
        let mut pool = ExecPool::new(width);
        let mut got = vec![-7i64; plan.out_len()];
        plan.execute_par(&input, &mut pool, &mut got);
        assert_eq!(got, want, "std_regular diverged at width {width}");
    }
}

#[test]
fn dw_all_mappings_pinned_across_widths() {
    // DBIS (Double × Combined), reconfig (Double × Split) and the
    // regular dw baseline, 144 pixels = 3 blocks
    let mut rng = Rng::new(402);
    let (h, w, c, k) = (14, 14, 16, 3);
    let input = rand_vec(&mut rng, h * w * c);
    let bank = FilterBank::new(rand_vec(&mut rng, c * k * k), c, k * k);
    let fcc = fcc_transform(&bank);
    let filters = rand_vec(&mut rng, c * k * k);
    let plans = [
        ("dbis", PlannedDwConv::fcc(h, w, c, &fcc, k, 1, false)),
        ("reconfig", PlannedDwConv::fcc(h, w, c, &fcc, k, 1, true)),
        ("regular", PlannedDwConv::regular(h, w, c, &filters, k, 1)),
    ];
    for (name, plan) in &plans {
        let mut ctx = ExecCtx::new();
        let mut want = vec![0i64; plan.out_len()];
        plan.execute(&input, &mut ctx, &mut want);
        for width in WIDTHS {
            let mut pool = ExecPool::new(width);
            let mut got = vec![-7i64; plan.out_len()];
            plan.execute_par(&input, &mut pool, &mut got);
            assert_eq!(&got, &want, "dw {name} diverged at width {width}");
        }
    }
}

#[test]
fn batched_execute_equals_per_image_across_widths() {
    // the session-batching unit: batch folded into the pixel dimension
    // must equal `batch` separate executes, at every width
    let mut rng = Rng::new(403);
    let (h, w, c, k, n, batch) = (10, 10, 3, 3, 8, 5);
    let bank = FilterBank::new(rand_vec(&mut rng, n * k * k * c), n, k * k * c);
    let fcc = fcc_transform(&bank);
    let plan = PlannedConv::std_fcc(h, w, c, &fcc, k, 1);
    let img = h * w * c;
    let inputs = rand_vec(&mut rng, batch * img);
    let mut ctx = ExecCtx::new();
    let mut want = vec![0i64; batch * plan.out_len()];
    for bi in 0..batch {
        plan.execute(
            &inputs[bi * img..(bi + 1) * img],
            &mut ctx,
            &mut want[bi * plan.out_len()..(bi + 1) * plan.out_len()],
        );
    }
    for width in WIDTHS {
        let mut pool = ExecPool::new(width);
        let mut got = vec![-7i64; batch * plan.out_len()];
        plan.execute_batch_par(&inputs, batch, &mut pool, &mut got);
        assert_eq!(got, want, "batched execute diverged at width {width}");
    }
}

/// Satellite pin (widths {1, 4}): the pooled dense MVM kernels must be
/// byte-identical to the serial kernels — every output row's wrapping
/// adds happen inside exactly one work unit, so scheduling cannot
/// reorder them.  Shapes cover the single-block shortcut, a ragged
/// tail block and a block-aligned row count.
#[test]
fn dense_mvm_kernels_pinned_at_widths_1_and_4() {
    let mut rng = Rng::new(406);
    for &(b, l, n) in &[(1usize, 5usize, 4usize), (50, 18, 9), (96, 12, 16)] {
        let x = rand_vec(&mut rng, b * l);
        let w = rand_vec(&mut rng, l * n);
        let want = mvm_i32(&x, &w, b, l, n);
        let half = n / 2;
        let bank = FilterBank::new(rand_vec(&mut rng, 2 * half * l), 2 * half, l);
        let fcc = fcc_transform(&bank);
        let fcc_want = fcc_mvm_i32(&x, &fcc.stored_even_cols(), &fcc.means, b, l, half);
        for width in [1usize, 4] {
            let mut pool = ExecPool::new(width);
            let mut got = vec![-7i32; b * n];
            mvm_i32_into_par(&mut got, &x, &w, b, l, n, &mut pool);
            assert_eq!(got, want, "mvm_i32 diverged at b={b} l={l} n={n} width={width}");
            let mut fcc_got = vec![-7i32; b * 2 * half];
            let mut psum = vec![0i32; b * half];
            fcc_mvm_into_par(
                &mut fcc_got,
                &mut psum,
                &x,
                &fcc.stored_even_cols(),
                &fcc.means,
                b,
                l,
                half,
                &mut pool,
            );
            assert_eq!(fcc_got, fcc_want, "fcc_mvm diverged at b={b} width={width}");
        }
    }
}

#[test]
fn session_logits_pinned_across_widths_and_fabrics() {
    // end to end: the full session stack at every pool width must match
    // the width-1 logits, on both fabric choices (the dense path now
    // shards MVM row blocks through the same pool)
    let mut rng = Rng::new(404);
    let batch = 3;
    let x: Vec<f32> = (0..batch * IMG_ELEMS).map(|_| rng.normal() as f32).collect();
    for fabric in [FabricChoice::DenseReference, FabricChoice::BitSliced] {
        let want = ReferenceBackend::seeded_with(0xDDC0, fabric)
            .with_threads(1)
            .infer_batch(&x, batch)
            .unwrap();
        for width in WIDTHS {
            let got = ReferenceBackend::seeded_with(0xDDC0, fabric)
                .with_threads(width)
                .infer_batch(&x, batch)
                .unwrap();
            assert_eq!(got, want, "{fabric:?} logits drifted at width {width}");
        }
    }
}

#[test]
fn batched_session_equals_per_image_sessions() {
    // ROADMAP session-batching item: one batched infer through the
    // fabric session == the same images one at a time, and both equal
    // the dense reference logits at these layer sizes
    let mut rng = Rng::new(405);
    let batch = 4;
    let x: Vec<f32> = (0..batch * IMG_ELEMS).map(|_| rng.normal() as f32).collect();
    let be = ReferenceBackend::seeded_with(0xDDC0, FabricChoice::BitSliced).with_threads(4);
    let mut session = be.plan().unwrap();
    let mut batched = vec![0f32; batch * NUM_CLASSES];
    session.infer_batch_into(&x, batch, &mut batched).unwrap();
    let mut single = vec![0f32; NUM_CLASSES];
    for bi in 0..batch {
        session
            .infer_batch_into(&x[bi * IMG_ELEMS..(bi + 1) * IMG_ELEMS], 1, &mut single)
            .unwrap();
        assert_eq!(
            &batched[bi * NUM_CLASSES..(bi + 1) * NUM_CLASSES],
            single.as_slice(),
            "image {bi}: batched fabric session drifted from per-image"
        );
    }
    let dense = ReferenceBackend::seeded_with(0xDDC0, FabricChoice::DenseReference)
        .infer_batch(&x, batch)
        .unwrap();
    assert_eq!(batched, dense, "fabric batch drifted from the dense kernel");
}

#[test]
fn parallel_sessions_keep_weights_resident() {
    // the residency invariant survives pool dispatch: executes at any
    // width perform zero SRAM weight writes
    let be = ReferenceBackend::seeded_with(0xDDC0, FabricChoice::BitSliced).with_threads(8);
    let mut session = be.plan().unwrap();
    let written = session.fabric_weight_writes();
    assert!(written > 0, "bitsliced plan must write conv weights");
    let x = vec![0.4f32; 2 * IMG_ELEMS];
    let mut out = vec![0f32; 2 * NUM_CLASSES];
    for _ in 0..3 {
        session.infer_batch_into(&x, 2, &mut out).unwrap();
    }
    assert_eq!(session.fabric_weight_writes(), written, "parallel execute wrote weights");
}
