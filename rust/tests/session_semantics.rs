//! Integration: the plan/execute session lifecycle (PR 3 tentpole).
//!
//! Pins the two contracts the redesign introduced:
//!
//! * **No semantic drift** — a prepared [`Session`] is deterministic
//!   across repeated `infer_batch_into` calls and byte-identical to the
//!   one-shot `Backend::infer_batch` path; the planned executors
//!   ([`PlannedConv`]/[`PlannedDwConv`]) reproduce the `exec_*`-era
//!   outputs across Regular/Double × Combined/Split mappings (seeded,
//!   vs the direct-conv oracles).
//! * **Weight residency** — planning writes SRAM weights exactly once;
//!   the `&self` execute path never writes again (asserted via the
//!   weight-write counters).

use ddc_pim::arch::pim_core::MacroGeometry;
use ddc_pim::fcc::{fcc_transform, recompose, FilterBank};
use ddc_pim::mapping::exec::{
    exec_dw_fcc, exec_dw_regular, exec_std_fcc, exec_std_regular, ExecCtx, PlannedConv,
    PlannedDwConv,
};
use ddc_pim::mapping::im2col::{direct_conv, direct_dwconv};
use ddc_pim::runtime::{
    reference::{ReferenceBackend, DEFAULT_SEED},
    Backend, FabricChoice, Session, IMG_ELEMS, NUM_CLASSES,
};
use ddc_pim::util::rng::Rng;

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<i32> {
    (0..n).map(|_| rng.int8() as i32).collect()
}

fn image(rng: &mut Rng) -> Vec<f32> {
    (0..IMG_ELEMS).map(|_| rng.normal() as f32).collect()
}

#[test]
fn session_is_byte_identical_to_one_shot_path() {
    let mut backend = ReferenceBackend::seeded(DEFAULT_SEED);
    let mut rng = Rng::new(31);
    let batch = 5;
    let x: Vec<f32> = (0..batch).flat_map(|_| image(&mut rng)).collect();
    let one_shot = backend.infer_batch(&x, batch).expect("one-shot");
    let mut session = backend.prepare().expect("prepare");
    let mut out = vec![0f32; batch * NUM_CLASSES];
    session.infer_batch_into(&x, batch, &mut out).expect("session");
    assert_eq!(out, one_shot, "session drifted from the one-shot path");
}

#[test]
fn repeated_session_calls_are_deterministic() {
    let backend = ReferenceBackend::seeded(DEFAULT_SEED);
    let mut session = backend.prepare().expect("prepare");
    let mut rng = Rng::new(32);
    let a = image(&mut rng);
    let b = image(&mut rng);
    let mut la1 = vec![0f32; NUM_CLASSES];
    let mut lb = vec![0f32; NUM_CLASSES];
    let mut la2 = vec![0f32; NUM_CLASSES];
    session.infer_batch_into(&a, 1, &mut la1).expect("a#1");
    session.infer_batch_into(&b, 1, &mut lb).expect("b");
    session.infer_batch_into(&a, 1, &mut la2).expect("a#2");
    assert_eq!(la1, la2, "interleaved inputs leaked state between calls");
    assert_ne!(la1, lb, "logits insensitive to input");
}

#[test]
fn session_batch_equals_per_image_calls() {
    // the real batch dimension must not change per-image results
    let backend = ReferenceBackend::seeded(DEFAULT_SEED);
    let mut session = backend.prepare().expect("prepare");
    let mut rng = Rng::new(33);
    let batch = 3;
    let imgs: Vec<Vec<f32>> = (0..batch).map(|_| image(&mut rng)).collect();
    let x: Vec<f32> = imgs.iter().flatten().copied().collect();
    let mut batched = vec![0f32; batch * NUM_CLASSES];
    session.infer_batch_into(&x, batch, &mut batched).expect("batched");
    for (i, img) in imgs.iter().enumerate() {
        let mut single = vec![0f32; NUM_CLASSES];
        session.infer_batch_into(img, 1, &mut single).expect("single");
        assert_eq!(
            &batched[i * NUM_CLASSES..(i + 1) * NUM_CLASSES],
            single.as_slice(),
            "batch row {i} differs from its single-image run"
        );
    }
}

#[test]
fn bitsliced_fabric_session_matches_dense_reference() {
    // the serving path on the bit-sliced fabric must agree exactly with
    // the dense fcc_mvm kernel (no i32 overflow at these layer sizes)
    let dense = ReferenceBackend::seeded(DEFAULT_SEED);
    let fabric = ReferenceBackend::seeded_with(DEFAULT_SEED, FabricChoice::BitSliced);
    let mut ds = dense.prepare().expect("dense prepare");
    let mut fs = fabric.prepare().expect("fabric prepare");
    let mut rng = Rng::new(34);
    let batch = 2;
    let x: Vec<f32> = (0..batch).flat_map(|_| image(&mut rng)).collect();
    let mut dout = vec![0f32; batch * NUM_CLASSES];
    let mut fout = vec![0f32; batch * NUM_CLASSES];
    ds.infer_batch_into(&x, batch, &mut dout).expect("dense");
    fs.infer_batch_into(&x, batch, &mut fout).expect("fabric");
    assert_eq!(dout, fout, "bit-sliced fabric drifted from the dense kernel");
}

#[test]
fn wide_geometry_fabric_session_matches_dense_reference() {
    // the >64-compartment envelope end to end: a 128-compartment macro
    // geometry (multi-word weight planes — hard-rejected at plan time
    // before this PR) must serve the full CIFAR stack and agree exactly
    // with the dense reference kernel, which is itself pinned to the
    // scalar oracle by the differential suite
    let dense = ReferenceBackend::seeded(DEFAULT_SEED);
    let wide = ReferenceBackend::seeded_with(DEFAULT_SEED, FabricChoice::BitSliced)
        .with_macro_geometry(MacroGeometry::with_compartments(128));
    let mut ds = dense.prepare().expect("dense prepare");
    let mut ws = wide.prepare().expect("wide fabric prepare");
    let mut rng = Rng::new(36);
    let batch = 2;
    let x: Vec<f32> = (0..batch).flat_map(|_| image(&mut rng)).collect();
    let mut dout = vec![0f32; batch * NUM_CLASSES];
    let mut wout = vec![0f32; batch * NUM_CLASSES];
    ds.infer_batch_into(&x, batch, &mut dout).expect("dense");
    ws.infer_batch_into(&x, batch, &mut wout).expect("wide fabric");
    assert_eq!(dout, wout, "128-compartment fabric drifted from the dense kernel");
}

#[test]
fn fabric_session_writes_weights_once() {
    let backend = ReferenceBackend::seeded_with(DEFAULT_SEED, FabricChoice::BitSliced);
    let mut session = backend.plan().expect("plan");
    let written = session.fabric_weight_writes();
    assert!(written > 0, "bitsliced planning must write conv weights");
    let mut rng = Rng::new(35);
    let img = image(&mut rng);
    let mut out = vec![0f32; NUM_CLASSES];
    for _ in 0..3 {
        session.infer_batch_into(&img, 1, &mut out).expect("infer");
    }
    assert_eq!(
        session.fabric_weight_writes(),
        written,
        "execute path wrote SRAM weights"
    );
}

/// Seeded pins of every planned mapping against its direct-conv
/// oracle, with ONE shared ExecCtx across all plans and repeated
/// executes — Regular/Double × Combined/Split coverage:
///
/// * std regular — Regular mode, Combined grouping
/// * std FCC — Double mode, Combined grouping
/// * dw FCC (DBIS) — Double mode, Combined grouping, per-pair rows
/// * dw FCC (reconfig) — Double mode, Split grouping, two stages
/// * dw regular — Regular mode, Combined grouping
#[test]
fn planned_executors_pin_exec_era_outputs() {
    let mut rng = Rng::new(0x5E55_10);
    let mut ctx = ExecCtx::new();
    let (h, w) = (5, 4);

    // std paths
    let (c, k, n) = (3, 3, 8);
    let input = rand_vec(&mut rng, h * w * c);
    let l = k * k * c;
    let bank = FilterBank::new(rand_vec(&mut rng, n * l), n, l);
    let fcc = fcc_transform(&bank);

    let plan = PlannedConv::std_fcc(h, w, c, &fcc, k, 1);
    let mut out = vec![0i64; plan.out_len()];
    for round in 0..2 {
        plan.execute(&input, &mut ctx, &mut out);
        let oracle = direct_conv(&input, h, w, c, &recompose(&fcc).data, n, k, 1);
        assert_eq!(out, oracle, "std_fcc drifted (round {round})");
        assert_eq!(out, exec_std_fcc(&input, h, w, c, &fcc, k, 1));
    }

    let plan = PlannedConv::std_regular(h, w, c, &bank.data, n, k, 1);
    let mut out = vec![0i64; plan.out_len()];
    plan.execute(&input, &mut ctx, &mut out);
    assert_eq!(out, direct_conv(&input, h, w, c, &bank.data, n, k, 1));
    assert_eq!(out, exec_std_regular(&input, h, w, c, &bank.data, n, k, 1));

    // dw paths (even channel count for the FCC pairs)
    let c = 10;
    let dw_input = rand_vec(&mut rng, h * w * c);
    let dw_bank = FilterBank::new(rand_vec(&mut rng, c * k * k), c, k * k);
    let dw_fcc = fcc_transform(&dw_bank);

    for reconfig in [false, true] {
        let plan = PlannedDwConv::fcc(h, w, c, &dw_fcc, k, 1, reconfig);
        let mut out = vec![0i64; plan.out_len()];
        plan.execute(&dw_input, &mut ctx, &mut out);
        let oracle = direct_dwconv(&dw_input, h, w, c, &recompose(&dw_fcc).data, k, 1);
        assert_eq!(out, oracle, "dw_fcc reconfig={reconfig} drifted");
        assert_eq!(out, exec_dw_fcc(&dw_input, h, w, c, &dw_fcc, k, 1, reconfig));
    }

    let plan = PlannedDwConv::regular(h, w, c, &dw_bank.data, k, 1);
    let mut out = vec![0i64; plan.out_len()];
    plan.execute(&dw_input, &mut ctx, &mut out);
    assert_eq!(out, direct_dwconv(&dw_input, h, w, c, &dw_bank.data, k, 1));
    assert_eq!(out, exec_dw_regular(&dw_input, h, w, c, &dw_bank.data, k, 1));
}

#[test]
fn planned_dw_residency_and_multipass() {
    // enough channels to overflow one pass worth of rows (64) on the
    // DBIS path: 160 channels = 80 pairs -> 2 passes of <= 64 rows
    let mut rng = Rng::new(0x5E55_11);
    let (h, w, c, k) = (2, 2, 160, 3);
    let input = rand_vec(&mut rng, h * w * c);
    let bank = FilterBank::new(rand_vec(&mut rng, c * k * k), c, k * k);
    let fcc = fcc_transform(&bank);
    let plan = PlannedDwConv::fcc(h, w, c, &fcc, k, 1, false);
    assert!(plan.load_passes() >= 2, "80 pairs must not fit one 64-row pass");
    let written = plan.weight_writes();
    assert!(written > 0);
    let mut ctx = ExecCtx::new();
    let mut out = vec![0i64; plan.out_len()];
    for _ in 0..2 {
        plan.execute(&input, &mut ctx, &mut out);
    }
    assert_eq!(plan.weight_writes(), written, "execute wrote weights");
    assert_eq!(out, direct_dwconv(&input, h, w, c, &recompose(&fcc).data, k, 1));
}
