//! Integration: the weight-streaming session (PR 6 tentpole).
//!
//! Pins the one contract that makes streaming safe to turn on: a
//! capacity budget changes *when weights are resident*, never *what
//! the network computes*.  Logits from a streamed session must be
//! byte-identical to the fully-resident session — across pass counts
//! {1, 2, 4}, on both fabrics, with prefetch on and off, and under
//! budgets small enough to force evictions and over-budget overflow
//! passes — while the [`CapacityPressure`] counters report the
//! pressure honestly.
//!
//! The subject network is `ReferenceBackend::seeded_deep(.., 2)`: the
//! seeded CIFAR stack plus two extra conv3x3(32->32) layers, stored
//! conv footprints [216, 2304, 4608, 4608] B (FCC-halved), so the
//! greedy pass planner yields 1 / 2 / 4 passes at budgets
//! 16384 / 9300 / 2400 B (the 2400 B budget makes each 4608 B layer an
//! over-budget overflow pass of its own).

use ddc_pim::arch::fault::UpsetConfig;
use ddc_pim::runtime::{
    reference::{ReferenceBackend, StreamConfig, DEFAULT_SEED},
    FabricChoice, Session, IMG_ELEMS, NUM_CLASSES,
};
use ddc_pim::util::rng::Rng;

const EXTRA_CONVS: usize = 2;

fn batch_input(seed: u64, batch: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..batch * IMG_ELEMS).map(|_| rng.normal() as f32).collect()
}

/// Logits from the fully-resident (non-streamed) deep session.
fn resident_logits(fabric: FabricChoice, x: &[f32], batch: usize) -> Vec<f32> {
    let be = ReferenceBackend::seeded_deep(DEFAULT_SEED, fabric, EXTRA_CONVS);
    let mut s = be.plan().expect("resident plan");
    let mut out = vec![0f32; batch * NUM_CLASSES];
    s.infer_batch_into(x, batch, &mut out).expect("resident infer");
    out
}

#[test]
fn streamed_logits_match_resident_across_pass_counts_and_fabrics() {
    let batch = 3;
    let x = batch_input(0x57E4_01, batch);
    for fabric in [FabricChoice::DenseReference, FabricChoice::BitSliced] {
        let want = resident_logits(fabric, &x, batch);
        for (budget, want_passes) in [(16384usize, 1usize), (9300, 2), (2400, 4)] {
            let be = ReferenceBackend::seeded_deep(DEFAULT_SEED, fabric, EXTRA_CONVS)
                .with_streaming(StreamConfig::budget(budget));
            let mut s = be.plan().expect("streamed plan");
            assert_eq!(
                s.streaming_passes(),
                Some(want_passes),
                "budget {budget} planned the wrong pass count on {fabric:?}"
            );
            let mut out = vec![0f32; batch * NUM_CLASSES];
            // two rounds: the second exercises the reload (wrap-around
            // prefetch) path, which must be just as exact
            for round in 0..2 {
                s.infer_batch_into(&x, batch, &mut out).expect("streamed infer");
                assert_eq!(
                    out, want,
                    "streamed logits drifted at budget {budget} on {fabric:?} (round {round})"
                );
            }
            let p = s.capacity_pressure_stats().expect("streamed pressure");
            if want_passes == 1 {
                assert_eq!(p.reloads, 0, "a fitting stack must never reload");
            } else {
                // round 2 re-acquires every pass it has already seen
                assert_eq!(
                    p.reloads,
                    want_passes as u64,
                    "budget {budget} reload count on {fabric:?}"
                );
                assert!(p.evictions > 0, "pass switches must evict");
            }
        }
    }
}

#[test]
fn prefetch_and_synchronous_staging_agree_exactly() {
    // prefetch changes *when* staging work happens (overlapped on the
    // stager thread vs inline), never the staged bytes or the logits
    let batch = 2;
    let x = batch_input(0x57E4_02, batch);
    let budget = 9300;
    let mut outs: Vec<Vec<f32>> = Vec::new();
    let mut counters = Vec::new();
    for cfg in [StreamConfig::budget(budget), StreamConfig::synchronous(budget)] {
        let be = ReferenceBackend::seeded_deep(DEFAULT_SEED, FabricChoice::BitSliced, EXTRA_CONVS)
            .with_streaming(cfg);
        let mut s = be.plan().expect("plan");
        let mut out = vec![0f32; batch * NUM_CLASSES];
        for _ in 0..3 {
            s.infer_batch_into(&x, batch, &mut out).expect("infer");
        }
        let p = s.capacity_pressure_stats().expect("pressure");
        outs.push(out);
        counters.push((p.reloads, p.evictions, p.overflows, p.staged_bytes, p.peak_resident_bytes));
    }
    assert_eq!(outs[0], outs[1], "prefetch changed the logits");
    assert_eq!(counters[0], counters[1], "prefetch changed the pressure bookkeeping");
}

#[test]
fn eviction_and_overflow_forcing_budget_stays_byte_identical() {
    // 300 B holds conv1 (216 B) but nothing else: every other conv is
    // an over-budget overflow pass, evicted and restaged per batch
    let batch = 2;
    let x = batch_input(0x57E4_03, batch);
    for fabric in [FabricChoice::DenseReference, FabricChoice::BitSliced] {
        let want = resident_logits(fabric, &x, batch);
        let be = ReferenceBackend::seeded_deep(DEFAULT_SEED, fabric, EXTRA_CONVS)
            .with_streaming(StreamConfig::budget(300));
        let mut s = be.plan().expect("plan");
        assert_eq!(s.streaming_passes(), Some(4));
        let mut out = vec![0f32; batch * NUM_CLASSES];
        s.infer_batch_into(&x, batch, &mut out).expect("infer");
        assert_eq!(out, want, "overflow-pass logits drifted on {fabric:?}");
        let p = s.capacity_pressure_stats().expect("pressure");
        assert_eq!(p.overflows, 3, "2304 and 2x4608 B layers must overflow a 300 B budget");
        assert!(p.evictions > 0, "restaging must evict the previous pass");
        assert!(
            p.peak_occupancy() > 1.0,
            "an over-budget pass must report occupancy > 1.0, got {}",
            p.peak_occupancy()
        );
    }
}

#[test]
fn streamed_session_stays_deterministic_across_interleaved_inputs() {
    // pass reloads between calls must not leak state across batches
    let a = batch_input(0x57E4_04, 1);
    let b = batch_input(0x57E4_05, 1);
    let be = ReferenceBackend::seeded_deep(DEFAULT_SEED, FabricChoice::BitSliced, EXTRA_CONVS)
        .with_streaming(StreamConfig::budget(9300));
    let mut s = be.plan().expect("plan");
    let mut la1 = vec![0f32; NUM_CLASSES];
    let mut lb = vec![0f32; NUM_CLASSES];
    let mut la2 = vec![0f32; NUM_CLASSES];
    s.infer_batch_into(&a, 1, &mut la1).expect("a#1");
    s.infer_batch_into(&b, 1, &mut lb).expect("b");
    s.infer_batch_into(&a, 1, &mut la2).expect("a#2");
    assert_eq!(la1, la2, "reload passes leaked state between calls");
    assert_ne!(la1, lb, "logits insensitive to input");
}

#[test]
fn streamed_upsets_with_full_scrub_match_the_fault_free_resident_oracle() {
    // runtime upsets age only the *resident* pass (weights off-SRAM
    // cannot decay; a restaged pass arrives fresh with a reset batch
    // clock), and the serving-time scrub walks exactly the resident
    // stripe space.  At full scrub coverage every boundary, a streamed
    // session under continuous upsets — even with its prefetch stager
    // killed mid-soak — must stay byte-identical to the fault-free
    // fully-resident session, and every landed bit must be found.
    let batch = 2;
    let x = batch_input(0x57E4_06, batch);
    let want = resident_logits(FabricChoice::BitSliced, &x, batch);
    let be = ReferenceBackend::seeded_deep(DEFAULT_SEED, FabricChoice::BitSliced, EXTRA_CONVS)
        .with_streaming(StreamConfig::budget(9300))
        .with_upsets(UpsetConfig::from_ppm(0xBEEF, 20_000))
        .with_scrub_stripes(usize::MAX);
    let mut s = be.plan().expect("streamed upset plan");
    assert_eq!(s.streaming_passes(), Some(2));
    let mut out = vec![0f32; batch * NUM_CLASSES];
    for round in 0..5 {
        if round == 2 {
            assert!(s.debug_kill_stager(), "expected a live stager to kill");
        }
        s.infer_batch_into(&x, batch, &mut out).expect("streamed upset infer");
        assert_eq!(
            out, want,
            "round {round}: streamed upsets leaked into served logits"
        );
    }
    let r = s.reliability_stats();
    assert!(r.upset_bits > 0, "no upsets landed on the resident pass");
    assert_eq!(
        r.upset_bits, r.corrupt_bits_found,
        "streamed upset ledger did not reconcile: {r:?}"
    );
    assert!(r.stager_fallbacks >= 1, "stager kill must book a fallback");
    // a second full scrub over the just-scrubbed state is idempotent
    let first = s.scrub_fabric();
    let second = s.scrub_fabric();
    assert_eq!(
        first.faults_detected, second.faults_detected,
        "second full scrub found new damage with no tick in between"
    );
    assert_eq!(first.upset_bits, second.upset_bits, "scrub_fabric must not tick the clock");
}
