//! Offline substrate for the `anyhow` API (the subset this workspace
//! uses): [`Error`], [`Result`], the [`Context`] extension trait and the
//! `anyhow!` / `ensure!` / `bail!` macros.
//!
//! The build is fully offline (vendored path crates only), so instead of
//! the crates.io `anyhow` we ship this ~150-line drop-in.  Error values
//! carry a context chain: `Display` prints the outermost message,
//! `{:#}` prints the whole chain joined with `": "` — matching anyhow's
//! alternate formatting, which the CLI relies on for diagnostics.

use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-chained error value.
///
/// Unlike `std` error types this intentionally does **not** implement
/// `std::error::Error`, so the blanket `From<E: std::error::Error>`
/// conversion below stays coherent (same shape as the real anyhow).
pub struct Error {
    /// Outermost message first; deeper causes follow.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message.
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error {
            chain: vec![msg.to_string()],
        }
    }

    /// Push an outer context message onto the chain.
    pub fn context(mut self, msg: impl fmt::Display) -> Error {
        self.chain.insert(0, msg.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The root (innermost) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result`s whose error converts into [`Error`].
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("while loading");
        assert_eq!(format!("{e}"), "while loading");
        assert_eq!(format!("{e:#}"), "while loading: missing thing");
    }

    #[test]
    fn context_on_result() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.root_cause(), "missing thing");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn context_on_option() {
        let o: Option<u32> = None;
        assert!(o.context("was none").is_err());
        assert_eq!(Some(3u32).context("was none").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "x must be positive, got -1");
        assert_eq!(format!("{}", f(200).unwrap_err()), "too big");
        let e = anyhow!("plain {}", 7);
        assert_eq!(format!("{e}"), "plain 7");
        let from_string = anyhow!(String::from("already a string"));
        assert_eq!(format!("{from_string}"), "already a string");
    }
}
