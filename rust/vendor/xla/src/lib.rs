//! Compile-time stub of the `xla` crate (the API surface
//! `ddc_pim::runtime::pjrt` uses: PJRT client/executable, literals and
//! HLO-text parsing).
//!
//! Purpose: the `pjrt` cargo feature must *compile* on any host — CI
//! runners and dev machines have no native XLA installed — while the
//! actual PJRT execution path stays an explicit opt-in.  Every
//! constructor here returns [`Error::Unavailable`], so a `pjrt` build
//! degrades gracefully at runtime (`Runtime::cpu` fails with a clear
//! message and the backend factory falls back to the reference backend).
//!
//! To run real AOT artifacts, replace this path dependency in
//! `rust/Cargo.toml` with the published crate (`xla = "0.1.6"`, which
//! links `xla_extension`) — the module in `runtime/pjrt.rs` is written
//! against that crate's API (see DESIGN.md §Backends).

use std::fmt;
use std::path::Path;

/// Stub error: the native XLA/PJRT library is not linked.
#[derive(Debug, Clone)]
pub enum Error {
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: native XLA is not available in this build \
                 (vendored stub; swap rust/vendor/xla for the real `xla` crate)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Uninhabited marker: stub handles can never actually be constructed,
/// which lets the compiler prove the execution paths unreachable.
#[derive(Debug, Clone, Copy)]
enum Never {}

/// Element types a [`Literal`] can carry.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Host-side literal (stub: shape-only placeholder).
#[derive(Debug, Clone)]
pub struct Literal {
    _dims: Vec<i64>,
}

impl Literal {
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            _dims: vec![data.len() as i64],
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        Ok(Literal {
            _dims: dims.to_vec(),
        })
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::Unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module (stub: retains nothing).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle (uninstantiable in the stub).
pub struct PjRtClient {
    never: Never,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        match self.never {}
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match self.never {}
    }
}

/// Compiled executable handle (uninstantiable in the stub).
pub struct PjRtLoadedExecutable {
    never: Never,
}

impl PjRtLoadedExecutable {
    /// Execute with host literals; result buffers per (device, output).
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self.never {}
    }
}

/// Device buffer handle (uninstantiable in the stub).
pub struct PjRtBuffer {
    never: Never,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("native XLA is not available"));
    }

    #[test]
    fn literal_shape_ops_work_host_side() {
        let l = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2, 1]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
    }
}
